//! Online entropy-health monitor.
//!
//! The paper's trust story rests on the chaotic light source actually being
//! random — it cites passing the NIST SP800-22 battery — but a field source
//! degrades silently, and an offline CI battery cannot notice.  This module
//! audits the entropy pipeline *at serving time*: producer blocks are tapped
//! at a configurable low duty cycle ([`BlockTap`]), folded into per-stream
//! sliding bit windows, and each full window is scored by the hardened
//! (non-panicking) [`super::nist`] battery plus a most-common-value
//! min-entropy estimate (SP800-90B MCV) and a lag-1 serial-correlation
//! estimate.  A per-`(shard, stream)` [`Scorecard`] tracks the pass-rate
//! EWMA and consecutive failing windows; sustained failure raises a typed
//! [`HealthEvent`] that the engine logs, exposes over `/info`, and — when
//! `entropy_fallback = "digital"` is opted into — acts on by swapping the
//! sampling backend.
//!
//! The tap *copies* produced blocks and never consumes stream state, so the
//! replay contract is untouched: outputs stay bitwise identical per
//! `(seed, threads, prefetch, rule)` whether the monitor is on or off.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::nist;

/// Monitor knobs (the `[health]` config table / `--health-*` flags).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthConfig {
    /// Master switch: a disabled monitor ignores every observation.
    pub enabled: bool,
    /// Sliding analysis window length in bits.  4096 is the smallest
    /// window at which the full battery (matrix rank included) applies.
    pub window_bits: usize,
    /// Fraction of produced blocks tapped, `0 < duty <= 1`.  The battery
    /// cost is `O(window_bits)` per analyzed window, so a low duty keeps
    /// the monitor off the hot path.
    pub duty: f64,
    /// EWMA smoothing factor for the per-stream pass-rate score.
    pub ewma_alpha: f64,
    /// EWMA score below which a window counts as failing.
    pub fail_threshold: f64,
    /// Consecutive failing windows before a `Degraded` event fires.
    pub fail_consecutive: u32,
    /// Minimum acceptable MCV min-entropy (bits per bit) per window.
    pub min_entropy_floor: f64,
    /// Maximum acceptable |lag-1 serial correlation| per window.
    pub serial_corr_cap: f64,
}

impl Default for HealthConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            window_bits: 4096,
            duty: 0.05,
            ewma_alpha: 0.3,
            fail_threshold: 0.5,
            fail_consecutive: 2,
            min_entropy_floor: 0.9,
            serial_corr_cap: 0.2,
        }
    }
}

impl HealthConfig {
    /// Clamp every knob into its sane range (mirrors
    /// `PipelineOptions::sanitized`).
    pub fn sanitized(mut self) -> Self {
        self.window_bits = self.window_bits.clamp(256, 1 << 20);
        self.duty = if self.duty.is_finite() {
            self.duty.clamp(1.0 / 1024.0, 1.0)
        } else {
            HealthConfig::default().duty
        };
        self.ewma_alpha = if self.ewma_alpha.is_finite() {
            self.ewma_alpha.clamp(0.01, 1.0)
        } else {
            HealthConfig::default().ewma_alpha
        };
        self.fail_threshold = if self.fail_threshold.is_finite() {
            self.fail_threshold.clamp(0.0, 1.0)
        } else {
            HealthConfig::default().fail_threshold
        };
        self.fail_consecutive = self.fail_consecutive.max(1);
        self.min_entropy_floor = self.min_entropy_floor.clamp(0.0, 1.0);
        self.serial_corr_cap = self.serial_corr_cap.clamp(0.0, 1.0);
        self
    }
}

/// A sustained change in a stream's health, raised at most once per
/// transition (degraded -> recovered -> degraded ...).
#[derive(Debug, Clone, PartialEq)]
pub enum HealthEvent {
    /// The stream's pass-rate EWMA stayed below threshold for
    /// `fail_consecutive` windows.
    Degraded {
        shard: usize,
        stream: String,
        score: f64,
    },
    /// A previously degraded stream's EWMA moved back above threshold.
    Recovered {
        shard: usize,
        stream: String,
        score: f64,
    },
}

/// Public snapshot of one `(shard, stream)` scorecard (the `/info` rows).
#[derive(Debug, Clone, PartialEq)]
pub struct Scorecard {
    pub shard: usize,
    pub stream: String,
    /// Windows analyzed so far.
    pub windows: u64,
    /// Pass-rate EWMA in [0, 1].
    pub score_ewma: f64,
    /// Raw pass rate of the most recent window.
    pub last_score: f64,
    /// Current run of failing windows.
    pub consecutive_fails: u32,
    /// MCV min-entropy (bits/bit) of the most recent window.
    pub min_entropy: f64,
    /// Lag-1 serial correlation of the most recent window.
    pub serial_corr: f64,
    /// True while the stream is in the degraded state.
    pub degraded: bool,
}

#[derive(Debug, Default)]
struct StreamState {
    pending: Vec<u8>,
    windows: u64,
    ewma: f64,
    last_score: f64,
    consecutive_fails: u32,
    min_entropy: f64,
    serial_corr: f64,
    degraded: bool,
}

#[derive(Debug, Default)]
struct MonitorInner {
    cards: HashMap<(usize, String), StreamState>,
    events: Vec<HealthEvent>,
}

/// Thread-safe scorecard keeper shared by producer taps, the engine, and
/// the gateway's `/info` path.
#[derive(Debug)]
pub struct Monitor {
    cfg: HealthConfig,
    inner: Mutex<MonitorInner>,
    any_degraded: AtomicBool,
    observed_blocks: AtomicU64,
    analyzed_windows: AtomicU64,
}

impl Monitor {
    pub fn new(cfg: HealthConfig) -> Self {
        Self {
            cfg: cfg.sanitized(),
            inner: Mutex::new(MonitorInner::default()),
            any_degraded: AtomicBool::new(false),
            observed_blocks: AtomicU64::new(0),
            analyzed_windows: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    /// True while any monitored stream is in the degraded state.  Lock-free
    /// — the engine polls this per classify call.
    pub fn any_degraded(&self) -> bool {
        self.any_degraded.load(Ordering::Acquire)
    }

    /// Blocks seen by taps (post duty cycle).
    pub fn observed_blocks(&self) -> u64 {
        self.observed_blocks.load(Ordering::Relaxed)
    }

    /// Full windows scored so far, across all streams.
    pub fn analyzed_windows(&self) -> u64 {
        self.analyzed_windows.load(Ordering::Relaxed)
    }

    /// Observe one produced entropy block (a slice of f64 draws).  Bits are
    /// extracted by successive-pair comparison (`a > b`), which is unbiased
    /// for any continuous iid draw distribution — normals and realized
    /// weight planes alike — so one extractor serves every stream kind.
    pub fn observe_block(&self, shard: usize, stream: &str, block: &[f64]) {
        if !self.cfg.enabled || block.len() < 2 {
            return;
        }
        self.observed_blocks.fetch_add(1, Ordering::Relaxed);
        let mut bits = Vec::with_capacity(block.len() / 2);
        for pair in block.chunks_exact(2) {
            bits.push(u8::from(pair[0] > pair[1]));
        }
        self.ingest_bits(shard, stream, &bits);
    }

    /// Fold raw bits into the stream's window (the extraction-free core;
    /// also the fault-injection hook for tests).
    pub fn ingest_bits(&self, shard: usize, stream: &str, bits: &[u8]) {
        if !self.cfg.enabled || bits.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().expect("health monitor poisoned");
        let key = (shard, stream.to_string());
        let state = inner.cards.entry(key).or_default();
        state.pending.extend_from_slice(bits);
        let window = self.cfg.window_bits;
        let mut transitions: Vec<HealthEvent> = Vec::new();
        while state.pending.len() >= window {
            let analysis = analyze_window(&state.pending[..window], &self.cfg);
            state.pending.drain(..window);
            self.analyzed_windows.fetch_add(1, Ordering::Relaxed);
            state.windows += 1;
            state.last_score = analysis.score;
            state.min_entropy = analysis.min_entropy;
            state.serial_corr = analysis.serial_corr;
            state.ewma = if state.windows == 1 {
                analysis.score
            } else {
                self.cfg.ewma_alpha * analysis.score + (1.0 - self.cfg.ewma_alpha) * state.ewma
            };
            if state.ewma < self.cfg.fail_threshold {
                state.consecutive_fails += 1;
                if state.consecutive_fails >= self.cfg.fail_consecutive && !state.degraded {
                    state.degraded = true;
                    transitions.push(HealthEvent::Degraded {
                        shard,
                        stream: stream.to_string(),
                        score: state.ewma,
                    });
                }
            } else {
                state.consecutive_fails = 0;
                if state.degraded {
                    state.degraded = false;
                    transitions.push(HealthEvent::Recovered {
                        shard,
                        stream: stream.to_string(),
                        score: state.ewma,
                    });
                }
            }
        }
        if !transitions.is_empty() {
            inner.events.extend(transitions);
            let any = inner.cards.values().any(|s| s.degraded);
            self.any_degraded.store(any, Ordering::Release);
        }
    }

    /// Drain pending health events (Degraded / Recovered transitions).
    pub fn take_events(&self) -> Vec<HealthEvent> {
        std::mem::take(&mut self.inner.lock().expect("health monitor poisoned").events)
    }

    /// Snapshot every scorecard, ordered by `(shard, stream)` for stable
    /// `/info` output.
    pub fn scorecards(&self) -> Vec<Scorecard> {
        let inner = self.inner.lock().expect("health monitor poisoned");
        let mut out: Vec<Scorecard> = inner
            .cards
            .iter()
            .map(|((shard, stream), s)| Scorecard {
                shard: *shard,
                stream: stream.clone(),
                windows: s.windows,
                score_ewma: s.ewma,
                last_score: s.last_score,
                consecutive_fails: s.consecutive_fails,
                min_entropy: s.min_entropy,
                serial_corr: s.serial_corr,
                degraded: s.degraded,
            })
            .collect();
        out.sort_by(|a, b| (a.shard, &a.stream).cmp(&(b.shard, &b.stream)));
        out
    }

    fn duty_stride(&self) -> u64 {
        ((1.0 / self.cfg.duty).round() as u64).max(1)
    }
}

struct WindowAnalysis {
    score: f64,
    min_entropy: f64,
    serial_corr: f64,
}

/// Score one full window: fraction of applicable checks passed, where the
/// checks are every applicable battery test plus the min-entropy floor and
/// the serial-correlation cap.
fn analyze_window(bits: &[u8], cfg: &HealthConfig) -> WindowAnalysis {
    let battery = nist::run_battery(bits);
    let mut total = battery.results.len();
    let mut passed = battery.results.iter().filter(|r| r.pass).count();
    let min_entropy = mcv_min_entropy(bits);
    total += 1;
    passed += usize::from(min_entropy >= cfg.min_entropy_floor);
    let serial_corr = lag1_correlation(bits);
    total += 1;
    passed += usize::from(serial_corr.abs() <= cfg.serial_corr_cap);
    WindowAnalysis {
        score: passed as f64 / total.max(1) as f64,
        min_entropy,
        serial_corr,
    }
}

/// SP800-90B most-common-value min-entropy estimate over a bit window:
/// upper-confidence-bound the most common symbol's probability and return
/// `-log2` of it.  1.0 = perfectly balanced, 0.0 = constant.
pub fn mcv_min_entropy(bits: &[u8]) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    let n = bits.len() as f64;
    let ones = bits.iter().map(|&b| b as u64).sum::<u64>() as f64;
    let p_hat = (ones.max(n - ones)) / n;
    let p_u = (p_hat + 2.576 * (p_hat * (1.0 - p_hat) / n).sqrt()).min(1.0);
    -p_u.log2()
}

/// Lag-1 serial correlation of a bit window; constant windows report 1.0
/// (fully predictable).
pub fn lag1_correlation(bits: &[u8]) -> f64 {
    if bits.len() < 2 {
        return 1.0;
    }
    let n = bits.len() as f64;
    let mean = bits.iter().map(|&b| b as f64).sum::<f64>() / n;
    let var = mean * (1.0 - mean);
    if var <= f64::EPSILON {
        return 1.0;
    }
    let pairs = bits.len() - 1;
    let cov = bits
        .windows(2)
        .map(|w| (w[0] as f64 - mean) * (w[1] as f64 - mean))
        .sum::<f64>()
        / pairs as f64;
    cov / var
}

/// Producer-side tap handle: owned by one producer thread (or one sync
/// stream), it forwards every `stride`-th block to the shared [`Monitor`]
/// by copy.  It never touches generator state, so enabling it cannot
/// change a single delivered draw.
#[derive(Debug)]
pub struct BlockTap {
    monitor: Arc<Monitor>,
    shard: usize,
    stream: String,
    stride: u64,
    count: u64,
}

impl BlockTap {
    pub fn new(monitor: Arc<Monitor>, shard: usize, stream: impl Into<String>) -> Self {
        let stride = monitor.duty_stride();
        Self {
            monitor,
            shard,
            stream: stream.into(),
            stride,
            count: 0,
        }
    }

    /// Observe one produced block (duty-cycled: the first block and every
    /// `stride`-th block thereafter are analyzed; the rest are free).
    pub fn observe(&mut self, block: &[f64]) {
        let idx = self.count;
        self.count += 1;
        if idx % self.stride != 0 {
            return;
        }
        self.monitor.observe_block(self.shard, &self.stream, block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::{BitSource, Xoshiro256pp};

    fn cfg(window_bits: usize) -> HealthConfig {
        HealthConfig {
            enabled: true,
            window_bits,
            duty: 1.0,
            ewma_alpha: 1.0,
            fail_threshold: 0.6,
            fail_consecutive: 1,
            ..HealthConfig::default()
        }
    }

    fn prng_bits(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256pp::new(seed);
        (0..n).map(|_| u8::from(rng.next_f64() < 0.5)).collect()
    }

    #[test]
    fn good_stream_stays_healthy() {
        let mon = Monitor::new(cfg(512));
        let mut rng = Xoshiro256pp::new(11);
        for _ in 0..8 {
            let block: Vec<f64> = (0..1024).map(|_| rng.next_f64()).collect();
            mon.observe_block(0, "dig-s0", &block);
        }
        assert!(mon.analyzed_windows() >= 4);
        assert!(!mon.any_degraded());
        assert!(mon.take_events().is_empty());
        let cards = mon.scorecards();
        assert_eq!(cards.len(), 1);
        assert!(cards[0].score_ewma > 0.6, "ewma {}", cards[0].score_ewma);
        assert!(cards[0].min_entropy > 0.8);
        assert!(!cards[0].degraded);
    }

    #[test]
    fn biased_stream_flags_within_one_window() {
        // 80/20 bias: monobit, block frequency, runs, cusum, apen and the
        // min-entropy floor all fail inside a single 512-bit window
        let mon = Monitor::new(cfg(512));
        let mut rng = Xoshiro256pp::new(7);
        let bits: Vec<u8> = (0..512).map(|_| u8::from(rng.next_f64() < 0.8)).collect();
        mon.ingest_bits(2, "dig-s2", &bits);
        assert!(mon.any_degraded());
        let events = mon.take_events();
        assert!(
            matches!(&events[..], [HealthEvent::Degraded { shard: 2, .. }]),
            "{events:?}"
        );
        let card = &mon.scorecards()[0];
        assert_eq!(card.windows, 1);
        assert!(card.degraded);
        assert!(card.min_entropy < 0.9, "min-entropy {}", card.min_entropy);
    }

    #[test]
    fn correlated_stream_flags_within_one_window() {
        // repeat-with-p = 0.85: runs, serial, approximate entropy and the
        // correlation cap all trip
        let mon = Monitor::new(cfg(512));
        let mut rng = Xoshiro256pp::new(9);
        let mut bit = 0u8;
        let bits: Vec<u8> = (0..512)
            .map(|_| {
                if rng.next_f64() >= 0.85 {
                    bit ^= 1;
                }
                bit
            })
            .collect();
        mon.ingest_bits(0, "dig-s0", &bits);
        assert!(mon.any_degraded());
        let card = &mon.scorecards()[0];
        assert!(card.serial_corr > 0.2, "corr {}", card.serial_corr);
    }

    #[test]
    fn stuck_channel_chaotic_blocks_flag_within_one_window() {
        // a chaotic source with stuck channels: draws round-robin over 9
        // channels, channels 0..4 pinned at a constant intensity.  The
        // pair-comparison extractor turns that into heavily structured
        // bits and the scorecard must flag it within one window.
        let mon = Monitor::new(cfg(512));
        let mut rng = Xoshiro256pp::new(13);
        let block: Vec<f64> = (0..2048)
            .map(|i| if i % 9 < 4 { 2.0 } else { rng.next_f64() })
            .collect();
        mon.observe_block(1, "pho-s1", &block);
        assert!(mon.analyzed_windows() >= 1);
        assert!(mon.any_degraded(), "scorecard: {:?}", mon.scorecards());
    }

    #[test]
    fn degraded_stream_recovers_and_raises_both_events() {
        let mut c = cfg(512);
        c.ewma_alpha = 1.0; // no smoothing: transitions happen immediately
        let mon = Monitor::new(c);
        let bad = vec![1u8; 512];
        mon.ingest_bits(0, "s", &bad);
        assert!(mon.any_degraded());
        mon.ingest_bits(0, "s", &prng_bits(512, 21));
        assert!(!mon.any_degraded());
        let events = mon.take_events();
        assert!(matches!(events[0], HealthEvent::Degraded { .. }));
        assert!(matches!(events[1], HealthEvent::Recovered { .. }));
    }

    #[test]
    fn consecutive_failure_threshold_delays_the_event() {
        let mut c = cfg(512);
        c.fail_consecutive = 3;
        let mon = Monitor::new(c);
        let bad = vec![0u8; 512];
        mon.ingest_bits(0, "s", &bad);
        mon.ingest_bits(0, "s", &bad);
        assert!(!mon.any_degraded(), "two failing windows < threshold of 3");
        mon.ingest_bits(0, "s", &bad);
        assert!(mon.any_degraded());
    }

    #[test]
    fn duty_cycle_skips_blocks_and_disabled_monitor_ignores_all() {
        let mut c = cfg(512);
        c.duty = 0.25;
        let mon = Arc::new(Monitor::new(c));
        let mut tap = BlockTap::new(mon.clone(), 0, "s");
        let block = vec![0.5f64; 64];
        for _ in 0..8 {
            tap.observe(&block);
        }
        assert_eq!(mon.observed_blocks(), 2, "every 4th block + the first");

        let off = Monitor::new(HealthConfig::default()); // enabled: false
        off.observe_block(0, "s", &[1.0; 1024]);
        off.ingest_bits(0, "s", &[1; 4096]);
        assert_eq!(off.observed_blocks(), 0);
        assert!(off.scorecards().is_empty());
        assert!(!off.any_degraded());
    }

    #[test]
    fn estimators_match_known_streams() {
        // balanced alternating bits: full min-entropy, strong negative
        // lag-1 correlation
        let alt: Vec<u8> = (0..4096).map(|i| (i % 2) as u8).collect();
        assert!(mcv_min_entropy(&alt) > 0.9);
        assert!(lag1_correlation(&alt) < -0.99);
        // constant bits: zero min-entropy, fully predictable
        let konst = vec![1u8; 4096];
        assert_eq!(lag1_correlation(&konst), 1.0);
        assert!(mcv_min_entropy(&konst) <= 0.0 + 1e-12);
        // fair random bits: high min-entropy, near-zero correlation
        let fair = prng_bits(65_536, 3);
        assert!(mcv_min_entropy(&fair) > 0.95);
        assert!(lag1_correlation(&fair).abs() < 0.05);
        // degenerate inputs are total, not panics
        assert_eq!(mcv_min_entropy(&[]), 0.0);
        assert_eq!(lag1_correlation(&[]), 1.0);
        assert_eq!(lag1_correlation(&[1]), 1.0);
    }

    #[test]
    fn sanitize_clamps_hostile_configs() {
        let c = HealthConfig {
            enabled: true,
            window_bits: 0,
            duty: f64::NAN,
            ewma_alpha: -3.0,
            fail_threshold: 7.0,
            fail_consecutive: 0,
            min_entropy_floor: 55.0,
            serial_corr_cap: -1.0,
        }
        .sanitized();
        assert_eq!(c.window_bits, 256);
        assert!(c.duty > 0.0 && c.duty <= 1.0);
        assert!(c.ewma_alpha >= 0.01 && c.ewma_alpha <= 1.0);
        assert!((0.0..=1.0).contains(&c.fail_threshold));
        assert_eq!(c.fail_consecutive, 1);
        assert!((0.0..=1.0).contains(&c.min_entropy_floor));
        assert!((0.0..=1.0).contains(&c.serial_corr_cap));
    }
}
