//! Gamma-distributed sampling (Marsaglia–Tsang squeeze method).
//!
//! Filtered thermal/chaotic light has Gamma-distributed integrated intensity:
//! a channel of optical bandwidth `B` integrated over a window `T` has
//! `M ≈ B·T + 1` speckle degrees of freedom, giving shape `M` and mean power
//! `P` — i.e. `I ~ Gamma(M, P/M)` with `std = P/√M`.  This is exactly the
//! physical knob the paper uses: *power programs the mean, bandwidth the
//! standard deviation* (Fig. 1(c), Fig. S2).

use super::gaussian::Gaussian;
use super::BitSource;

/// Sample `Gamma(shape, scale)` (shape > 0).
///
/// Marsaglia & Tsang (2000): for shape >= 1 use the squeeze method; for
/// shape < 1 use the boost `Gamma(a) = Gamma(a+1) * U^{1/a}`.
pub fn sample_gamma<R: BitSource>(rng: &mut R, g: &mut Gaussian, shape: f64, scale: f64) -> f64 {
    debug_assert!(shape > 0.0 && scale > 0.0);
    if shape < 1.0 {
        let u = rng.next_f64().max(1e-300);
        return sample_gamma(rng, g, shape + 1.0, scale) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = g.sample(rng);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.next_f64();
        let x2 = x * x;
        if u < 1.0 - 0.0331 * x2 * x2 {
            return d * v3 * scale;
        }
        if u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln()) {
            return d * v3 * scale;
        }
    }
}

/// Convenience: chaotic-light intensity sample with mean `power` and
/// `dof = B·T + 1` degrees of freedom (std = power / sqrt(dof)).
#[inline]
pub fn sample_intensity<R: BitSource>(
    rng: &mut R,
    g: &mut Gaussian,
    power: f64,
    dof: f64,
) -> f64 {
    sample_gamma(rng, g, dof, power / dof)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::Xoshiro256pp;
    use crate::util::mathstat::Welford;

    fn moments(shape: f64, scale: f64, n: usize) -> (f64, f64) {
        let mut rng = Xoshiro256pp::new(12);
        let mut g = Gaussian::new();
        let mut w = Welford::new();
        for _ in 0..n {
            w.push(sample_gamma(&mut rng, &mut g, shape, scale));
        }
        (w.mean(), w.std())
    }

    #[test]
    fn gamma_moments_shape_large() {
        let (m, s) = moments(5.6, 2.0, 100_000);
        assert!((m - 11.2).abs() < 0.1, "mean {m}");
        assert!((s - (5.6f64).sqrt() * 2.0).abs() < 0.1, "std {s}");
    }

    #[test]
    fn gamma_moments_shape_below_one() {
        let (m, s) = moments(0.94, 1.0, 200_000);
        assert!((m - 0.94).abs() < 0.02, "mean {m}");
        assert!((s - (0.94f64).sqrt()).abs() < 0.02, "std {s}");
    }

    #[test]
    fn gamma_is_positive() {
        let mut rng = Xoshiro256pp::new(9);
        let mut g = Gaussian::new();
        for _ in 0..10_000 {
            assert!(sample_gamma(&mut rng, &mut g, 1.9, 0.5) > 0.0);
        }
    }

    #[test]
    fn intensity_bandwidth_programs_std() {
        // doubling the degrees of freedom shrinks relative std by sqrt(2):
        // the paper's "bandwidth programs the standard deviation" knob.
        let mut rng = Xoshiro256pp::new(4);
        let mut g = Gaussian::new();
        let mut w_lo = Welford::new();
        let mut w_hi = Welford::new();
        for _ in 0..100_000 {
            w_lo.push(sample_intensity(&mut rng, &mut g, 1.0, 1.9375)); // B=25 GHz
            w_hi.push(sample_intensity(&mut rng, &mut g, 1.0, 6.625)); // B=150 GHz
        }
        assert!((w_lo.mean() - 1.0).abs() < 0.01);
        assert!((w_hi.mean() - 1.0).abs() < 0.01);
        let ratio = w_lo.std() / w_hi.std();
        let expect = (6.625f64 / 1.9375).sqrt();
        assert!((ratio - expect).abs() < 0.05, "ratio {ratio} expect {expect}");
    }
}
