//! Chaotic-light entropy source model (erbium ASE).
//!
//! Models the broadband amplified-spontaneous-emission source the paper
//! uses as a true random number generator (26): a spectrally-sliced channel
//! with optical bandwidth `B`, integrated over a window `T`, yields an
//! intensity `I ~ Gamma(M, P/M)` with `M = B·T + 1` speckle modes and mean
//! power `P`.  Different spectral slices are statistically independent (12),
//! which the simulator realizes with jump-decorrelated PRNG streams per
//! channel.
//!
//! Besides powering the photonic machine simulator, the source doubles as
//! the serving-time noise provider for the *surrogate* execution path: the
//! normalized intensity `(I − P) / (P/√M)` is the physical analogue of the
//! unit-variance `eps` operand of the L1 kernel.

use super::gamma::sample_intensity;
use super::gaussian::Gaussian;
use super::xoshiro::Xoshiro256pp;


/// Physical constants of the source (paper, System Architecture).
#[derive(Debug, Clone)]
pub struct SourceConfig {
    /// Number of independent spectral channels (weights). Paper: 9.
    pub channels: usize,
    /// Integration window per symbol in ps (3 samples at 80 GSPS).
    pub t_symbol_ps: f64,
    /// Minimum programmable channel bandwidth (GHz). Paper: 25.
    pub bw_min_ghz: f64,
    /// Maximum programmable channel bandwidth (GHz). Paper: 150.
    pub bw_max_ghz: f64,
}

impl Default for SourceConfig {
    fn default() -> Self {
        Self {
            channels: 9,
            t_symbol_ps: 37.5,
            bw_min_ghz: 25.0,
            bw_max_ghz: 150.0,
        }
    }
}

impl SourceConfig {
    /// Speckle degrees of freedom for a channel bandwidth (GHz).
    pub fn dof(&self, bw_ghz: f64) -> f64 {
        1.0 + bw_ghz * 1e9 * self.t_symbol_ps * 1e-12
    }

    /// The smallest relative std the source can realize: `1/sqrt(dof_max)`.
    pub fn min_rel_sigma(&self) -> f64 {
        1.0 / self.dof(self.bw_max_ghz).sqrt()
    }

    /// The largest relative std (single rail): `1/sqrt(dof_min)`.
    pub fn max_rel_sigma(&self) -> f64 {
        1.0 / self.dof(self.bw_min_ghz).sqrt()
    }
}

/// One independent spectral slice of the ASE source.
#[derive(Debug, Clone)]
struct Channel {
    rng: Xoshiro256pp,
    gauss: Gaussian,
}

/// The chaotic light source: independent per-channel intensity streams.
#[derive(Debug, Clone)]
pub struct ChaoticLightSource {
    pub cfg: SourceConfig,
    chans: Vec<Channel>,
}

impl ChaoticLightSource {
    pub fn new(cfg: SourceConfig, seed: u64) -> Self {
        let mut root = Xoshiro256pp::new(seed);
        let chans = (0..cfg.channels)
            .map(|_| Channel {
                rng: root.fork(),
                gauss: Gaussian::new(),
            })
            .collect();
        Self { cfg, chans }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(SourceConfig::default(), seed)
    }

    /// One intensity sample from channel `ch` at mean power `power` and
    /// bandwidth `bw_ghz`.  This is the physical weight-sampling primitive.
    #[inline]
    pub fn intensity(&mut self, ch: usize, power: f64, bw_ghz: f64) -> f64 {
        let dof = self.cfg.dof(bw_ghz);
        let c = &mut self.chans[ch];
        sample_intensity(&mut c.rng, &mut c.gauss, power, dof)
    }

    /// One intensity sample with a precomputed degrees-of-freedom value
    /// (hot-path variant: skips the bandwidth -> dof conversion).
    #[inline]
    pub fn intensity_dof(&mut self, ch: usize, power: f64, dof: f64) -> f64 {
        let c = &mut self.chans[ch];
        sample_intensity(&mut c.rng, &mut c.gauss, power, dof)
    }

    /// Bulk intensity draws from channel `ch` — the fill-style variant of
    /// [`Self::intensity_dof`] for the conv inner loop: one Gamma draw per
    /// slot from the channel's own decorrelated stream, identical values in
    /// identical order to the scalar calls.
    pub fn fill_intensity_dof(&mut self, ch: usize, power: f64, dof: f64, out: &mut [f64]) {
        let c = &mut self.chans[ch];
        for slot in out {
            *slot = sample_intensity(&mut c.rng, &mut c.gauss, power, dof);
        }
    }

    /// Bulk *differential-pair* draws from channel `ch`: per slot, one draw
    /// at `p_plus` then one at `p_minus` — the exact stream consumption
    /// order of the scalar plus-then-minus rail sampling in the conv loop,
    /// so the bulk refactor stays bit-identical for two-rail taps.
    pub fn fill_intensity_pair_dof(
        &mut self,
        ch: usize,
        p_plus: f64,
        p_minus: f64,
        dof: f64,
        plus: &mut [f64],
        minus: &mut [f64],
    ) {
        let c = &mut self.chans[ch];
        for (pl, mi) in plus.iter_mut().zip(minus.iter_mut()) {
            *pl = sample_intensity(&mut c.rng, &mut c.gauss, p_plus, dof);
            *mi = sample_intensity(&mut c.rng, &mut c.gauss, p_minus, dof);
        }
    }

    /// Normalized intensity: `(I - P) / (P/sqrt(M))` — zero mean, unit std.
    /// The physical analogue of the surrogate's `eps` operand.
    #[inline]
    pub fn normalized(&mut self, ch: usize, bw_ghz: f64) -> f64 {
        let dof = self.cfg.dof(bw_ghz);
        let i = self.intensity_dof(ch, 1.0, dof);
        (i - 1.0) * dof.sqrt()
    }

    /// Fill an `eps` buffer with normalized chaotic noise, cycling channels.
    /// Used by the serving engine for the surrogate path and by the SVI
    /// trainer for reparameterization noise.
    ///
    /// Channel-outer with strided writes: the old per-element `i % nch`
    /// channel select is hoisted out of the inner loop.  Because every
    /// channel owns an independent stream, the emitted values are identical
    /// to the historical interleaved order.
    pub fn fill_eps(&mut self, bw_ghz: f64, out: &mut [f32]) {
        let nch = self.chans.len();
        let dof = self.cfg.dof(bw_ghz);
        let scale = dof.sqrt();
        for (ch, c) in self.chans.iter_mut().enumerate() {
            if ch >= out.len() {
                break;
            }
            for slot in out[ch..].iter_mut().step_by(nch) {
                let i = sample_intensity(&mut c.rng, &mut c.gauss, 1.0, dof);
                *slot = ((i - 1.0) * scale) as f32;
            }
        }
    }

    /// Extract unbiased random bits by comparing successive intensity
    /// samples (exactly unbiased for i.i.d. draws).  This is the stream the
    /// NIST SP800-22 battery is run on (paper: the ASE source passes it).
    pub fn extract_bits(&mut self, bw_ghz: f64, nbits: usize) -> Vec<u8> {
        let dof = self.cfg.dof(bw_ghz);
        let nch = self.chans.len();
        let mut bits = Vec::with_capacity(nbits);
        let mut ch = 0;
        while bits.len() < nbits {
            let a = self.intensity_dof(ch, 1.0, dof);
            let b = self.intensity_dof(ch, 1.0, dof);
            if a != b {
                bits.push(u8::from(a > b));
            }
            ch = (ch + 1) % nch;
        }
        bits
    }
}

/// Bulk *realized-weight* draws for one differential tap: per slot, one
/// intensity at `p_plus` (if lit) then one at `p_minus` (if lit) from the
/// same stream, combined as `gain_eff * (I⁺ − I⁻)`.  This is the block API
/// of the entropy pipeline: a free-running producer thread calls it against
/// its own `(rng, gauss)` stream exactly as the synchronous fallback does,
/// so the emitted weight sequence is identical either way.  The stream
/// consumption per slot (plus-then-minus, skipping dark rails) matches the
/// conv core's historical rail sampling order.
pub fn fill_realized_weights<R: crate::entropy::BitSource>(
    rng: &mut R,
    gauss: &mut Gaussian,
    p_plus: f64,
    p_minus: f64,
    dof: f64,
    gain_eff: f64,
    out: &mut [f64],
) {
    for slot in out {
        let plus = if p_plus > 0.0 {
            sample_intensity(rng, gauss, p_plus, dof)
        } else {
            0.0
        };
        let minus = if p_minus > 0.0 {
            sample_intensity(rng, gauss, p_minus, dof)
        } else {
            0.0
        };
        *slot = gain_eff * (plus - minus);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::Welford;

    #[test]
    fn config_dof_and_sigma_range() {
        let cfg = SourceConfig::default();
        assert!((cfg.dof(25.0) - 1.9375).abs() < 1e-9);
        assert!((cfg.dof(150.0) - 6.625).abs() < 1e-9);
        // the paper's "~68 % change in standard deviation" knob
        let change = cfg.max_rel_sigma() / cfg.min_rel_sigma();
        assert!(change > 1.5 && change < 2.2, "sigma range {change}");
    }

    #[test]
    fn intensity_moments_follow_power_and_bandwidth() {
        let mut src = ChaoticLightSource::with_defaults(1);
        let mut w = Welford::new();
        for _ in 0..50_000 {
            w.push(src.intensity(0, 2.0, 150.0));
        }
        assert!((w.mean() - 2.0).abs() < 0.02, "mean {}", w.mean());
        let expect_std = 2.0 / (6.625f64).sqrt();
        assert!((w.std() - expect_std).abs() < 0.02, "std {}", w.std());
    }

    #[test]
    fn channels_are_uncorrelated() {
        let mut src = ChaoticLightSource::with_defaults(2);
        let n = 20_000;
        let a: Vec<f64> = (0..n).map(|_| src.normalized(0, 100.0)).collect();
        let b: Vec<f64> = (0..n).map(|_| src.normalized(1, 100.0)).collect();
        let corr: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum::<f64>() / n as f64;
        assert!(corr.abs() < 0.02, "corr {corr}");
    }

    #[test]
    fn normalized_has_unit_moments() {
        let mut src = ChaoticLightSource::with_defaults(3);
        let mut w = Welford::new();
        for _ in 0..100_000 {
            w.push(src.normalized(4, 150.0));
        }
        assert!(w.mean().abs() < 0.02, "mean {}", w.mean());
        assert!((w.std() - 1.0).abs() < 0.02, "std {}", w.std());
    }

    #[test]
    fn eps_fill_covers_buffer() {
        let mut src = ChaoticLightSource::with_defaults(4);
        let mut buf = vec![0.0f32; 5000];
        src.fill_eps(150.0, &mut buf);
        let m = crate::util::mathstat::mean_f32(&buf);
        let s = crate::util::mathstat::std_f32(&buf);
        assert!(m.abs() < 0.1 && (s - 1.0).abs() < 0.1, "m {m} s {s}");
    }

    #[test]
    fn extracted_bits_balanced() {
        let mut src = ChaoticLightSource::with_defaults(5);
        let bits = src.extract_bits(100.0, 20_000);
        let ones = bits.iter().map(|&b| b as usize).sum::<usize>();
        let frac = ones as f64 / bits.len() as f64;
        assert!((frac - 0.5).abs() < 0.02, "ones {frac}");
    }

    #[test]
    fn fill_eps_matches_interleaved_scalar_order() {
        // the hoisted channel-outer fill must emit exactly what the old
        // `i % nch` interleaved scalar loop emitted
        let mut bulk_src = ChaoticLightSource::with_defaults(11);
        let mut buf = vec![0.0f32; 1003]; // non-multiple of nch on purpose
        bulk_src.fill_eps(150.0, &mut buf);

        let mut scalar_src = ChaoticLightSource::with_defaults(11);
        let nch = scalar_src.cfg.channels;
        for (i, &v) in buf.iter().enumerate() {
            let want = scalar_src.normalized(i % nch, 150.0) as f32;
            assert_eq!(v, want, "slot {i}");
        }
    }

    #[test]
    fn bulk_intensity_matches_scalar_stream() {
        let mut a = ChaoticLightSource::with_defaults(13);
        let mut bulk = vec![0.0f64; 500];
        a.fill_intensity_dof(3, 2.0, 5.0, &mut bulk);

        let mut b = ChaoticLightSource::with_defaults(13);
        for (i, &v) in bulk.iter().enumerate() {
            assert_eq!(v, b.intensity_dof(3, 2.0, 5.0), "draw {i}");
        }
    }

    #[test]
    fn paired_bulk_matches_interleaved_scalar_stream() {
        let (pp, pm, dof) = (1.4, 0.6, 4.0);
        let mut a = ChaoticLightSource::with_defaults(19);
        let mut plus = vec![0.0f64; 300];
        let mut minus = vec![0.0f64; 300];
        a.fill_intensity_pair_dof(2, pp, pm, dof, &mut plus, &mut minus);

        let mut b = ChaoticLightSource::with_defaults(19);
        for i in 0..300 {
            assert_eq!(plus[i], b.intensity_dof(2, pp, dof), "plus {i}");
            assert_eq!(minus[i], b.intensity_dof(2, pm, dof), "minus {i}");
        }
    }

    #[test]
    fn realized_weight_fill_matches_scalar_rail_order_and_moments() {
        let (pp, pm, dof, ge) = (1.2, 0.4, 5.0, 0.8);
        let mut rng = Xoshiro256pp::new(23);
        let mut gauss = Gaussian::new();
        let mut w = vec![0.0f64; 40_000];
        fill_realized_weights(&mut rng, &mut gauss, pp, pm, dof, ge, &mut w);

        // same stream, scalar plus-then-minus draws -> identical values
        let mut rng2 = Xoshiro256pp::new(23);
        let mut g2 = Gaussian::new();
        for (i, &v) in w.iter().take(200).enumerate() {
            let plus = sample_intensity(&mut rng2, &mut g2, pp, dof);
            let minus = sample_intensity(&mut rng2, &mut g2, pm, dof);
            assert_eq!(v, ge * (plus - minus), "slot {i}");
        }

        let mut st = Welford::new();
        for &v in &w {
            st.push(v);
        }
        let want_mu = ge * (pp - pm);
        let want_sd = ge * ((pp * pp + pm * pm) / dof).sqrt();
        assert!((st.mean() - want_mu).abs() < 0.02, "mean {}", st.mean());
        assert!((st.std() - want_sd).abs() < 0.02, "std {}", st.std());

        // a dark rail consumes no draws: single-rail fill == plus-only scalar
        let mut a = Xoshiro256pp::new(29);
        let mut ga = Gaussian::new();
        let mut single = vec![0.0f64; 64];
        fill_realized_weights(&mut a, &mut ga, pp, 0.0, dof, ge, &mut single);
        let mut b = Xoshiro256pp::new(29);
        let mut gb = Gaussian::new();
        for (i, &v) in single.iter().enumerate() {
            assert_eq!(v, ge * sample_intensity(&mut b, &mut gb, pp, dof), "slot {i}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ChaoticLightSource::with_defaults(7);
        let mut b = ChaoticLightSource::with_defaults(7);
        for ch in 0..9 {
            assert_eq!(a.intensity(ch, 1.0, 80.0), b.intensity(ch, 1.0, 80.0));
        }
    }
}
