//! NIST SP800-22 statistical test battery (seven-test subset).
//!
//! The paper states the ASE entropy source "passes the state-of-the-art
//! National Institute of Standards and Technology (NIST Special Publication
//! 800-22) tests for entropy sources" (26).  This module implements the
//! seven core tests so the claim is *checked in CI* against the simulated
//! chaotic source (and can be run against any bit stream via `pbm nist`):
//!
//! 1. Frequency (monobit)          5. Cumulative sums (forward/backward)
//! 2. Block frequency              6. Approximate entropy
//! 3. Runs                         7. Serial (two p-values)
//! 4. Longest run of ones          8. Discrete Fourier (spectral)
//!                                 9. Binary matrix rank
//!
//! Each test returns a p-value; a stream passes at significance
//! `alpha = 0.01` (the SP800-22 default).
//!
//! Every test is total over arbitrary input: streams too short for a test
//! yield a typed [`NistError`] instead of a panic, so the online entropy
//! health monitor ([`crate::entropy::health`]) can feed production tap
//! windows through the battery unconditionally.  [`run_battery`] runs the
//! applicable subset and records the skipped tests with their reasons.

use std::fmt;

use crate::util::fft::real_fft_magnitudes;
use crate::util::mathstat::{erfc, igamc};

/// Result of one test.
#[derive(Debug, Clone)]
pub struct TestResult {
    pub name: &'static str,
    pub p_value: f64,
    pub pass: bool,
}

/// Why a test could not be applied to a stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NistError {
    /// The stream is empty.
    Empty { test: &'static str },
    /// The stream is shorter than the test's minimum input length (bits).
    TooShort {
        test: &'static str,
        needed: usize,
        got: usize,
    },
}

impl fmt::Display for NistError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NistError::Empty { test } => write!(f, "{test}: empty bit stream"),
            NistError::TooShort { test, needed, got } => {
                write!(f, "{test}: needs >= {needed} bits, got {got}")
            }
        }
    }
}

impl std::error::Error for NistError {}

pub const ALPHA: f64 = 0.01;

fn result(name: &'static str, p: f64) -> TestResult {
    TestResult {
        name,
        p_value: p,
        pass: p >= ALPHA,
    }
}

/// Applicability guard shared by the tests: empty and too-short streams
/// become typed errors instead of NaN p-values or panics.
fn require(test: &'static str, bits: &[u8], needed: usize) -> Result<(), NistError> {
    if bits.is_empty() {
        Err(NistError::Empty { test })
    } else if bits.len() < needed {
        Err(NistError::TooShort {
            test,
            needed,
            got: bits.len(),
        })
    } else {
        Ok(())
    }
}

/// 2.1 Frequency (monobit) test.
pub fn frequency(bits: &[u8]) -> Result<TestResult, NistError> {
    require("frequency", bits, 1)?;
    let n = bits.len() as f64;
    let s: i64 = bits.iter().map(|&b| if b == 1 { 1i64 } else { -1 }).sum();
    let s_obs = (s as f64).abs() / n.sqrt();
    Ok(result("frequency", erfc(s_obs / std::f64::consts::SQRT_2)))
}

/// 2.2 Block frequency test with block size `m` (clamped to >= 1).
pub fn block_frequency(bits: &[u8], m: usize) -> Result<TestResult, NistError> {
    let m = m.max(1);
    require("block_frequency", bits, m)?;
    let nblocks = bits.len() / m;
    let mut chi2 = 0.0;
    for b in 0..nblocks {
        let ones = bits[b * m..(b + 1) * m].iter().map(|&x| x as usize).sum::<usize>();
        let pi = ones as f64 / m as f64;
        chi2 += (pi - 0.5) * (pi - 0.5);
    }
    chi2 *= 4.0 * m as f64;
    Ok(result(
        "block_frequency",
        igamc(nblocks as f64 / 2.0, chi2 / 2.0),
    ))
}

/// 2.3 Runs test.
pub fn runs(bits: &[u8]) -> Result<TestResult, NistError> {
    require("runs", bits, 2)?;
    let n = bits.len() as f64;
    let pi = bits.iter().map(|&b| b as f64).sum::<f64>() / n;
    // prerequisite: frequency test must be applicable
    if (pi - 0.5).abs() >= 2.0 / n.sqrt() {
        return Ok(result("runs", 0.0));
    }
    let mut v = 1u64;
    for w in bits.windows(2) {
        if w[0] != w[1] {
            v += 1;
        }
    }
    let num = (v as f64 - 2.0 * n * pi * (1.0 - pi)).abs();
    let den = 2.0 * (2.0 * n).sqrt() * pi * (1.0 - pi);
    Ok(result("runs", erfc(num / den)))
}

/// 2.4 Longest run of ones in 8-bit blocks (n >= 128 variant).
pub fn longest_run(bits: &[u8]) -> Result<TestResult, NistError> {
    // SP800-22 Table 2-4 for M = 8: categories <=1, 2, 3, >=4
    const PI: [f64; 4] = [0.2148, 0.3672, 0.2305, 0.1875];
    let m = 8;
    require("longest_run", bits, 16 * m)?;
    let nblocks = bits.len() / m;
    let mut counts = [0f64; 4];
    for b in 0..nblocks {
        let mut longest = 0usize;
        let mut run = 0usize;
        for &bit in &bits[b * m..(b + 1) * m] {
            if bit == 1 {
                run += 1;
                longest = longest.max(run);
            } else {
                run = 0;
            }
        }
        let cat = match longest {
            0 | 1 => 0,
            2 => 1,
            3 => 2,
            _ => 3,
        };
        counts[cat] += 1.0;
    }
    let n = nblocks as f64;
    let chi2: f64 = (0..4)
        .map(|i| {
            let e = n * PI[i];
            (counts[i] - e) * (counts[i] - e) / e
        })
        .sum();
    Ok(result("longest_run", igamc(1.5, chi2 / 2.0)))
}

/// 2.13 Cumulative sums test (mode 0 = forward, 1 = backward).
///
/// Degenerate streams (`z_max == 0`, i.e. empty input — every bit moves the
/// walk by ±1, so any non-empty stream has `z_max >= 1`) return p = 0.0
/// (fail) instead of driving `n / z` to infinity: the saturated `as i64`
/// casts used to turn the series bounds into an astronomically long loop.
pub fn cusum(bits: &[u8], backward: bool) -> Result<TestResult, NistError> {
    let name = if backward { "cusum_backward" } else { "cusum_forward" };
    let n = bits.len();
    let mut z_max = 0i64;
    let mut s = 0i64;
    let iter: Box<dyn Iterator<Item = &u8>> = if backward {
        Box::new(bits.iter().rev())
    } else {
        Box::new(bits.iter())
    };
    for &b in iter {
        s += if b == 1 { 1 } else { -1 };
        z_max = z_max.max(s.abs());
    }
    if z_max == 0 {
        return Ok(result(name, 0.0));
    }
    let z = z_max as f64;
    let nf = n as f64;
    let sqrt_n = nf.sqrt();
    let phi = |x: f64| 0.5 * erfc(-x / std::f64::consts::SQRT_2);
    let mut sum1 = 0.0;
    let k_lo = ((-(nf / z) + 1.0) / 4.0).floor() as i64;
    let k_hi = ((nf / z - 1.0) / 4.0).floor() as i64;
    for k in k_lo..=k_hi {
        let kf = k as f64;
        sum1 += phi((4.0 * kf + 1.0) * z / sqrt_n) - phi((4.0 * kf - 1.0) * z / sqrt_n);
    }
    let mut sum2 = 0.0;
    let k_lo = ((-(nf / z) - 3.0) / 4.0).floor() as i64;
    for k in k_lo..=k_hi {
        let kf = k as f64;
        sum2 += phi((4.0 * kf + 3.0) * z / sqrt_n) - phi((4.0 * kf + 1.0) * z / sqrt_n);
    }
    Ok(result(name, (1.0 - sum1 + sum2).clamp(0.0, 1.0)))
}

fn phi_m(bits: &[u8], m: usize) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let n = bits.len();
    let mut counts = vec![0u64; 1 << m];
    let mask = (1usize << m) - 1;
    let mut idx = 0usize;
    // prime the window with wraparound
    for &b in bits.iter().take(m - 1) {
        idx = ((idx << 1) | b as usize) & mask;
    }
    for i in 0..n {
        let b = bits[(i + m - 1) % n];
        idx = ((idx << 1) | b as usize) & mask;
        counts[idx] += 1;
    }
    let nf = n as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / nf;
            p * p.ln()
        })
        .sum()
}

/// 2.12 Approximate entropy test with template length `m` (clamped >= 1).
pub fn approximate_entropy(bits: &[u8], m: usize) -> Result<TestResult, NistError> {
    require("approx_entropy", bits, 1)?;
    let m = m.max(1);
    let n = bits.len() as f64;
    let ap_en = phi_m(bits, m) - phi_m(bits, m + 1);
    let chi2 = 2.0 * n * (std::f64::consts::LN_2 - ap_en);
    Ok(result(
        "approx_entropy",
        igamc((1 << (m - 1)) as f64, chi2 / 2.0),
    ))
}

fn psi2(bits: &[u8], m: usize) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let n = bits.len();
    let mut counts = vec![0u64; 1 << m];
    let mask = (1usize << m) - 1;
    let mut idx = 0usize;
    for &b in bits.iter().take(m - 1) {
        idx = ((idx << 1) | b as usize) & mask;
    }
    for i in 0..n {
        let b = bits[(i + m - 1) % n];
        idx = ((idx << 1) | b as usize) & mask;
        counts[idx] += 1;
    }
    let nf = n as f64;
    counts.iter().map(|&c| (c as f64) * (c as f64)).sum::<f64>() * (1 << m) as f64 / nf - nf
}

/// 2.11 Serial test with template length `m` (clamped >= 2); returns both
/// p-values.
pub fn serial(bits: &[u8], m: usize) -> Result<(TestResult, TestResult), NistError> {
    require("serial", bits, 1)?;
    let m = m.max(2);
    let d1 = psi2(bits, m) - psi2(bits, m - 1);
    let d2 = psi2(bits, m) - 2.0 * psi2(bits, m - 1) + psi2(bits, m - 2);
    Ok((
        result("serial_p1", igamc((1 << (m - 2)) as f64, d1 / 2.0)),
        result("serial_p2", igamc((1usize << (m.saturating_sub(3))).max(1) as f64, d2 / 2.0)),
    ))
}

/// 2.6 Discrete Fourier Transform (spectral) test.
///
/// Detects periodic features: converts bits to ±1, takes the FFT magnitude
/// of the first half-spectrum, and compares the count of peaks below the
/// 95 % threshold `T = sqrt(ln(1/0.05) * n)` with its expectation `0.95 n/2`.
pub fn spectral(bits: &[u8]) -> Result<TestResult, NistError> {
    // empty input would shift-underflow the power-of-two truncation below
    // (usize::BITS - 1 - leading_zeros with len == 0)
    require("spectral", bits, 1)?;
    // truncate to a power of two (the reference implementation pads/truncs)
    let n = 1usize << (usize::BITS - 1 - bits.len().leading_zeros());
    let signal: Vec<f64> = bits[..n]
        .iter()
        .map(|&b| if b == 1 { 1.0 } else { -1.0 })
        .collect();
    let mags = real_fft_magnitudes(&signal);
    let t = ((1.0f64 / 0.05).ln() * n as f64).sqrt();
    let n0 = 0.95 * n as f64 / 2.0;
    let n1 = mags.iter().filter(|&&m| m < t).count() as f64;
    let d = (n1 - n0) / (n as f64 * 0.95 * 0.05 / 4.0).sqrt();
    Ok(result("spectral", erfc(d.abs() / std::f64::consts::SQRT_2)))
}

/// Rank of a 32x32 binary matrix over GF(2), rows as u32 bitmasks.
fn gf2_rank32(rows: &mut [u32; 32]) -> usize {
    let mut rank = 0usize;
    for col in (0..32).rev() {
        let bit = 1u32 << col;
        // find a pivot row at or below `rank`
        if let Some(p) = (rank..32).find(|&r| rows[r] & bit != 0) {
            rows.swap(rank, p);
            for r in 0..32 {
                if r != rank && rows[r] & bit != 0 {
                    rows[r] ^= rows[rank];
                }
            }
            rank += 1;
            if rank == 32 {
                break;
            }
        }
    }
    rank
}

/// 2.5 Binary matrix rank test (32x32 matrices).
///
/// Random binary matrices have full rank with p ≈ 0.2888, rank 31 with
/// p ≈ 0.5776, lower with p ≈ 0.1336; structure in the stream skews this.
pub fn matrix_rank(bits: &[u8]) -> Result<TestResult, NistError> {
    const P_FULL: f64 = 0.2888;
    const P_M1: f64 = 0.5776;
    const P_LO: f64 = 0.1336;
    let per_matrix = 32 * 32;
    require("matrix_rank", bits, 4 * per_matrix)?;
    let n_mat = bits.len() / per_matrix;
    let mut counts = [0f64; 3]; // full, full-1, lower
    for m in 0..n_mat {
        let chunk = &bits[m * per_matrix..(m + 1) * per_matrix];
        let mut rows = [0u32; 32];
        for (r, row) in rows.iter_mut().enumerate() {
            for c in 0..32 {
                *row = (*row << 1) | chunk[r * 32 + c] as u32;
            }
        }
        match gf2_rank32(&mut rows) {
            32 => counts[0] += 1.0,
            31 => counts[1] += 1.0,
            _ => counts[2] += 1.0,
        }
    }
    let n = n_mat as f64;
    let expect = [n * P_FULL, n * P_M1, n * P_LO];
    let chi2: f64 = counts
        .iter()
        .zip(&expect)
        .map(|(c, e)| (c - e) * (c - e) / e)
        .sum();
    Ok(result("matrix_rank", igamc(1.0, chi2 / 2.0)))
}

/// Outcome of a full battery run: the tests that applied (with their
/// p-values) and the tests skipped as inapplicable to this stream.
#[derive(Debug, Clone, Default)]
pub struct BatteryRun {
    pub results: Vec<TestResult>,
    pub skipped: Vec<NistError>,
}

impl BatteryRun {
    /// True when at least one test ran and every test that ran passed.
    pub fn all_pass(&self) -> bool {
        !self.results.is_empty() && self.results.iter().all(|r| r.pass)
    }

    fn push(&mut self, r: Result<TestResult, NistError>) {
        match r {
            Ok(t) => self.results.push(t),
            Err(e) => self.skipped.push(e),
        }
    }
}

/// Run the whole battery with SP800-22 default parameters.  Tests whose
/// minimum input length exceeds the stream are skipped — recorded with
/// their reasons in [`BatteryRun::skipped`] — instead of panicking, so the
/// battery is safe to run on production tap windows of any size.
pub fn run_battery(bits: &[u8]) -> BatteryRun {
    let mut out = BatteryRun::default();
    out.push(frequency(bits));
    out.push(block_frequency(bits, 128));
    out.push(runs(bits));
    out.push(longest_run(bits));
    out.push(cusum(bits, false));
    out.push(cusum(bits, true));
    out.push(approximate_entropy(bits, 8));
    out.push(spectral(bits));
    out.push(matrix_rank(bits));
    match serial(bits, 8) {
        Ok((s1, s2)) => {
            out.results.push(s1);
            out.results.push(s2);
        }
        Err(e) => out.skipped.push(e),
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::{BitSource, ChaoticLightSource, Xoshiro256pp};

    fn prng_bits(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256pp::new(seed);
        let mut bits = Vec::with_capacity(n);
        while bits.len() < n {
            let w = rng.next_u64();
            for i in 0..64 {
                bits.push(((w >> i) & 1) as u8);
            }
        }
        bits.truncate(n);
        bits
    }

    fn bitstring(s: &str) -> Vec<u8> {
        s.chars()
            .filter(|c| !c.is_whitespace())
            .map(|c| c as u8 - b'0')
            .collect()
    }

    // SP800-22 §2.1.8 / §2.3.8 / §2.13.8 worked example: the 100-bit
    // binary expansion used throughout the document's small examples.
    const EPS_100: &str = "11001001000011111101101010100010001000010110100011\
                           00001000110100110001001100011001100010100010111000";

    #[test]
    fn sp800_22_example_frequency() {
        // §2.1.8 worked example: P-value = 0.109599
        let r = frequency(&bitstring(EPS_100)).unwrap();
        assert!((r.p_value - 0.109599).abs() < 1e-4, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_runs() {
        // §2.3.8 example: P-value = 0.500798
        let r = runs(&bitstring(EPS_100)).unwrap();
        assert!((r.p_value - 0.500798).abs() < 1e-4, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_cusum() {
        // §2.13.8 example: forward P-value = 0.219194
        let r = cusum(&bitstring(EPS_100), false).unwrap();
        assert!((r.p_value - 0.219194).abs() < 1e-3, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_longest_run() {
        // §2.4.8 example: 128-bit stream, M = 8 blocks give category counts
        // ν = [4, 9, 3, 0] and P-value = 0.180609
        let eps = "11001100000101010110110001001100111000000000001001\
                   00110101010001000100111101011010000000110101111100\
                   1100111001101101100010110010";
        let r = longest_run(&bitstring(eps)).unwrap();
        assert!((r.p_value - 0.180609).abs() < 1e-3, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_approximate_entropy() {
        // §2.12.4 example: ε = 0100110101, m = 3, P-value = 0.261961
        let r = approximate_entropy(&bitstring("0100110101"), 3).unwrap();
        assert!((r.p_value - 0.261961).abs() < 1e-3, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_serial() {
        // §2.11.4 example: ε = 0011011101, m = 3 → ψ²₃ = 2.8, ψ²₂ = 1.2,
        // P-value1 = 0.808792, P-value2 = 0.670320
        let (s1, s2) = serial(&bitstring("0011011101"), 3).unwrap();
        assert!((s1.p_value - 0.808792).abs() < 1e-3, "p1 {}", s1.p_value);
        assert!((s2.p_value - 0.670320).abs() < 1e-3, "p2 {}", s2.p_value);
    }

    #[test]
    fn good_prng_passes_battery() {
        let bits = prng_bits(100_000, 42);
        let run = run_battery(&bits);
        assert!(run.skipped.is_empty(), "{:?}", run.skipped);
        for r in &run.results {
            assert!(r.pass, "{} failed: p = {}", r.name, r.p_value);
        }
    }

    #[test]
    fn chaotic_source_passes_battery() {
        // the paper's claim, checked against the simulated ASE source
        let mut src = ChaoticLightSource::with_defaults(2024);
        let bits = src.extract_bits(100.0, 100_000);
        let run = run_battery(&bits);
        assert!(run.all_pass());
        for r in &run.results {
            assert!(r.pass, "{} failed: p = {}", r.name, r.p_value);
        }
    }

    #[test]
    fn spectral_passes_prng_fails_periodic() {
        let bits = prng_bits(65_536, 21);
        let r = spectral(&bits).unwrap();
        assert!(r.pass, "p = {}", r.p_value);
        // strong periodic component
        let periodic: Vec<u8> = (0..65_536).map(|i| ((i / 4) % 2) as u8).collect();
        assert!(!spectral(&periodic).unwrap().pass);
    }

    #[test]
    fn matrix_rank_passes_prng_fails_lowrank() {
        let bits = prng_bits(64 * 1024, 22);
        let r = matrix_rank(&bits).unwrap();
        assert!(r.pass, "p = {}", r.p_value);
        // rank-1 matrices: every row identical
        let mut low = Vec::with_capacity(64 * 1024);
        let mut rng = Xoshiro256pp::new(23);
        while low.len() < 64 * 1024 {
            let row: Vec<u8> = (0..32).map(|_| u8::from(rng.next_f64() < 0.5)).collect();
            for _ in 0..32 {
                low.extend_from_slice(&row);
            }
        }
        assert!(!matrix_rank(&low).unwrap().pass);
    }

    #[test]
    fn gf2_rank_known_cases() {
        let mut id = [0u32; 32];
        for (i, r) in id.iter_mut().enumerate() {
            *r = 1 << i;
        }
        assert_eq!(gf2_rank32(&mut id.clone()), 32);
        let mut zero = [0u32; 32];
        assert_eq!(gf2_rank32(&mut zero), 0);
        let mut two = [0u32; 32];
        two[0] = 0b1011;
        two[1] = 0b0101;
        two[2] = 0b1110; // = row0 ^ row1
        assert_eq!(gf2_rank32(&mut two), 2);
    }

    #[test]
    fn constant_stream_fails() {
        let bits = vec![1u8; 10_000];
        assert!(!frequency(&bits).unwrap().pass);
    }

    #[test]
    fn alternating_stream_fails_runs() {
        let bits: Vec<u8> = (0..10_000).map(|i| (i % 2) as u8).collect();
        let r = runs(&bits).unwrap();
        assert!(!r.pass, "p = {}", r.p_value);
    }

    #[test]
    fn biased_stream_fails_battery() {
        // 60/40 bias must be caught by the monobit test at n = 100k
        let mut rng = Xoshiro256pp::new(7);
        let bits: Vec<u8> = (0..100_000)
            .map(|_| u8::from(rng.next_f64() < 0.6))
            .collect();
        assert!(!frequency(&bits).unwrap().pass);
    }

    #[test]
    fn periodic_structure_fails_serial_or_apen() {
        // embed an 8-bit periodic pattern with small jitter
        let mut rng = Xoshiro256pp::new(8);
        let pat = [1u8, 0, 1, 1, 0, 0, 1, 0];
        let bits: Vec<u8> = (0..50_000)
            .map(|i| {
                if rng.next_f64() < 0.9 {
                    pat[i % 8]
                } else {
                    u8::from(rng.next_f64() < 0.5)
                }
            })
            .collect();
        let battery = run_battery(&bits);
        assert!(battery.results.iter().any(|r| !r.pass));
    }

    #[test]
    fn short_and_empty_streams_are_typed_errors_not_panics() {
        assert_eq!(
            frequency(&[]).unwrap_err(),
            NistError::Empty { test: "frequency" }
        );
        assert!(matches!(
            block_frequency(&[1, 0, 1], 128),
            Err(NistError::TooShort {
                needed: 128,
                got: 3,
                ..
            })
        ));
        assert!(matches!(
            longest_run(&[1; 64]),
            Err(NistError::TooShort { needed: 128, .. })
        ));
        assert!(matches!(
            matrix_rank(&[0; 1024]),
            Err(NistError::TooShort { needed: 4096, .. })
        ));
        assert!(matches!(spectral(&[]), Err(NistError::Empty { .. })));
        assert!(matches!(
            approximate_entropy(&[], 8),
            Err(NistError::Empty { .. })
        ));
        assert!(matches!(serial(&[], 8), Err(NistError::Empty { .. })));
        // errors render readably for logs and /info
        let msg = NistError::TooShort {
            test: "longest_run",
            needed: 128,
            got: 64,
        }
        .to_string();
        assert!(msg.contains("longest_run") && msg.contains("128"), "{msg}");
    }

    #[test]
    fn battery_on_short_stream_skips_and_reports() {
        // 8 bits: frequency/runs/cusum/apen/spectral/serial apply; the
        // block tests do not — they are reported, not panicked on
        let run = run_battery(&[1, 0, 1, 1, 0, 0, 1, 0]);
        assert!(!run.results.is_empty());
        assert!(run
            .skipped
            .iter()
            .any(|e| matches!(e, NistError::TooShort { test: "longest_run", .. })));
        assert!(run
            .skipped
            .iter()
            .any(|e| matches!(e, NistError::TooShort { test: "matrix_rank", .. })));
        // the empty stream runs nothing but still reports every skip
        let empty = run_battery(&[]);
        assert!(!empty.all_pass());
        assert!(empty.results.iter().all(|r| !r.pass), "only degenerate cusum rows");
        assert!(!empty.skipped.is_empty());
    }

    #[test]
    fn cusum_degenerate_stream_fails_promptly() {
        // z_max == 0 (empty stream) used to drive n/z to infinity; the
        // saturated i64 series bounds then spun for ~2^62 iterations.
        // Degenerate streams now fail immediately with p = 0.
        for backward in [false, true] {
            let r = cusum(&[], backward).unwrap();
            assert_eq!(r.p_value, 0.0);
            assert!(!r.pass);
        }
        // non-degenerate path still matches the reference example
        assert!(cusum(&bitstring(EPS_100), true).unwrap().p_value > 0.0);
    }
}
