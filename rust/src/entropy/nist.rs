//! NIST SP800-22 statistical test battery (seven-test subset).
//!
//! The paper states the ASE entropy source "passes the state-of-the-art
//! National Institute of Standards and Technology (NIST Special Publication
//! 800-22) tests for entropy sources" (26).  This module implements the
//! seven core tests so the claim is *checked in CI* against the simulated
//! chaotic source (and can be run against any bit stream via `pbm nist`):
//!
//! 1. Frequency (monobit)          5. Cumulative sums (forward/backward)
//! 2. Block frequency              6. Approximate entropy
//! 3. Runs                         7. Serial (two p-values)
//! 4. Longest run of ones          8. Discrete Fourier (spectral)
//!                                 9. Binary matrix rank
//!
//! Each test returns a p-value; a stream passes at significance
//! `alpha = 0.01` (the SP800-22 default).

use crate::util::fft::real_fft_magnitudes;
use crate::util::mathstat::{erfc, igamc};

/// Result of one test.
#[derive(Debug, Clone)]
pub struct TestResult {
    pub name: &'static str,
    pub p_value: f64,
    pub pass: bool,
}

pub const ALPHA: f64 = 0.01;

fn result(name: &'static str, p: f64) -> TestResult {
    TestResult {
        name,
        p_value: p,
        pass: p >= ALPHA,
    }
}

/// 2.1 Frequency (monobit) test.
pub fn frequency(bits: &[u8]) -> TestResult {
    let n = bits.len() as f64;
    let s: i64 = bits.iter().map(|&b| if b == 1 { 1i64 } else { -1 }).sum();
    let s_obs = (s as f64).abs() / n.sqrt();
    result("frequency", erfc(s_obs / std::f64::consts::SQRT_2))
}

/// 2.2 Block frequency test with block size `m`.
pub fn block_frequency(bits: &[u8], m: usize) -> TestResult {
    let nblocks = bits.len() / m;
    assert!(nblocks > 0, "stream shorter than one block");
    let mut chi2 = 0.0;
    for b in 0..nblocks {
        let ones = bits[b * m..(b + 1) * m].iter().map(|&x| x as usize).sum::<usize>();
        let pi = ones as f64 / m as f64;
        chi2 += (pi - 0.5) * (pi - 0.5);
    }
    chi2 *= 4.0 * m as f64;
    result(
        "block_frequency",
        igamc(nblocks as f64 / 2.0, chi2 / 2.0),
    )
}

/// 2.3 Runs test.
pub fn runs(bits: &[u8]) -> TestResult {
    let n = bits.len() as f64;
    let pi = bits.iter().map(|&b| b as f64).sum::<f64>() / n;
    // prerequisite: frequency test must be applicable
    if (pi - 0.5).abs() >= 2.0 / n.sqrt() {
        return result("runs", 0.0);
    }
    let mut v = 1u64;
    for w in bits.windows(2) {
        if w[0] != w[1] {
            v += 1;
        }
    }
    let num = (v as f64 - 2.0 * n * pi * (1.0 - pi)).abs();
    let den = 2.0 * (2.0 * n).sqrt() * pi * (1.0 - pi);
    result("runs", erfc(num / den))
}

/// 2.4 Longest run of ones in 8-bit blocks (n >= 128 variant).
pub fn longest_run(bits: &[u8]) -> TestResult {
    // SP800-22 Table 2-4 for M = 8: categories <=1, 2, 3, >=4
    const PI: [f64; 4] = [0.2148, 0.3672, 0.2305, 0.1875];
    let m = 8;
    let nblocks = bits.len() / m;
    assert!(nblocks >= 16, "need >= 128 bits");
    let mut counts = [0f64; 4];
    for b in 0..nblocks {
        let mut longest = 0usize;
        let mut run = 0usize;
        for &bit in &bits[b * m..(b + 1) * m] {
            if bit == 1 {
                run += 1;
                longest = longest.max(run);
            } else {
                run = 0;
            }
        }
        let cat = match longest {
            0 | 1 => 0,
            2 => 1,
            3 => 2,
            _ => 3,
        };
        counts[cat] += 1.0;
    }
    let n = nblocks as f64;
    let chi2: f64 = (0..4)
        .map(|i| {
            let e = n * PI[i];
            (counts[i] - e) * (counts[i] - e) / e
        })
        .sum();
    result("longest_run", igamc(1.5, chi2 / 2.0))
}

/// 2.13 Cumulative sums test (mode 0 = forward, 1 = backward).
pub fn cusum(bits: &[u8], backward: bool) -> TestResult {
    let n = bits.len();
    let mut z_max = 0i64;
    let mut s = 0i64;
    let iter: Box<dyn Iterator<Item = &u8>> = if backward {
        Box::new(bits.iter().rev())
    } else {
        Box::new(bits.iter())
    };
    for &b in iter {
        s += if b == 1 { 1 } else { -1 };
        z_max = z_max.max(s.abs());
    }
    let z = z_max as f64;
    let nf = n as f64;
    let sqrt_n = nf.sqrt();
    let phi = |x: f64| 0.5 * erfc(-x / std::f64::consts::SQRT_2);
    let mut sum1 = 0.0;
    let k_lo = ((-(nf / z) + 1.0) / 4.0).floor() as i64;
    let k_hi = ((nf / z - 1.0) / 4.0).floor() as i64;
    for k in k_lo..=k_hi {
        let kf = k as f64;
        sum1 += phi((4.0 * kf + 1.0) * z / sqrt_n) - phi((4.0 * kf - 1.0) * z / sqrt_n);
    }
    let mut sum2 = 0.0;
    let k_lo = ((-(nf / z) - 3.0) / 4.0).floor() as i64;
    for k in k_lo..=k_hi {
        let kf = k as f64;
        sum2 += phi((4.0 * kf + 3.0) * z / sqrt_n) - phi((4.0 * kf + 1.0) * z / sqrt_n);
    }
    result(
        if backward { "cusum_backward" } else { "cusum_forward" },
        (1.0 - sum1 + sum2).clamp(0.0, 1.0),
    )
}

fn phi_m(bits: &[u8], m: usize) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let n = bits.len();
    let mut counts = vec![0u64; 1 << m];
    let mask = (1usize << m) - 1;
    let mut idx = 0usize;
    // prime the window with wraparound
    for &b in bits.iter().take(m - 1) {
        idx = ((idx << 1) | b as usize) & mask;
    }
    for i in 0..n {
        let b = bits[(i + m - 1) % n];
        idx = ((idx << 1) | b as usize) & mask;
        counts[idx] += 1;
    }
    let nf = n as f64;
    counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / nf;
            p * p.ln()
        })
        .sum()
}

/// 2.12 Approximate entropy test with template length `m`.
pub fn approximate_entropy(bits: &[u8], m: usize) -> TestResult {
    let n = bits.len() as f64;
    let ap_en = phi_m(bits, m) - phi_m(bits, m + 1);
    let chi2 = 2.0 * n * (std::f64::consts::LN_2 - ap_en);
    result(
        "approx_entropy",
        igamc((1 << (m - 1)) as f64, chi2 / 2.0),
    )
}

fn psi2(bits: &[u8], m: usize) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let n = bits.len();
    let mut counts = vec![0u64; 1 << m];
    let mask = (1usize << m) - 1;
    let mut idx = 0usize;
    for &b in bits.iter().take(m - 1) {
        idx = ((idx << 1) | b as usize) & mask;
    }
    for i in 0..n {
        let b = bits[(i + m - 1) % n];
        idx = ((idx << 1) | b as usize) & mask;
        counts[idx] += 1;
    }
    let nf = n as f64;
    counts.iter().map(|&c| (c as f64) * (c as f64)).sum::<f64>() * (1 << m) as f64 / nf - nf
}

/// 2.11 Serial test with template length `m`; returns both p-values.
pub fn serial(bits: &[u8], m: usize) -> (TestResult, TestResult) {
    let d1 = psi2(bits, m) - psi2(bits, m - 1);
    let d2 = psi2(bits, m) - 2.0 * psi2(bits, m - 1) + psi2(bits, m.saturating_sub(2));
    (
        result("serial_p1", igamc((1 << (m - 2)) as f64, d1 / 2.0)),
        result("serial_p2", igamc((1 << (m - 3)).max(1) as f64, d2 / 2.0)),
    )
}

/// 2.6 Discrete Fourier Transform (spectral) test.
///
/// Detects periodic features: converts bits to ±1, takes the FFT magnitude
/// of the first half-spectrum, and compares the count of peaks below the
/// 95 % threshold `T = sqrt(ln(1/0.05) * n)` with its expectation `0.95 n/2`.
pub fn spectral(bits: &[u8]) -> TestResult {
    // truncate to a power of two (the reference implementation pads/truncs)
    let n = 1usize << (usize::BITS - 1 - bits.len().leading_zeros());
    let signal: Vec<f64> = bits[..n]
        .iter()
        .map(|&b| if b == 1 { 1.0 } else { -1.0 })
        .collect();
    let mags = real_fft_magnitudes(&signal);
    let t = ((1.0f64 / 0.05).ln() * n as f64).sqrt();
    let n0 = 0.95 * n as f64 / 2.0;
    let n1 = mags.iter().filter(|&&m| m < t).count() as f64;
    let d = (n1 - n0) / (n as f64 * 0.95 * 0.05 / 4.0).sqrt();
    result("spectral", erfc(d.abs() / std::f64::consts::SQRT_2))
}

/// Rank of a 32x32 binary matrix over GF(2), rows as u32 bitmasks.
fn gf2_rank32(rows: &mut [u32; 32]) -> usize {
    let mut rank = 0usize;
    for col in (0..32).rev() {
        let bit = 1u32 << col;
        // find a pivot row at or below `rank`
        if let Some(p) = (rank..32).find(|&r| rows[r] & bit != 0) {
            rows.swap(rank, p);
            for r in 0..32 {
                if r != rank && rows[r] & bit != 0 {
                    rows[r] ^= rows[rank];
                }
            }
            rank += 1;
            if rank == 32 {
                break;
            }
        }
    }
    rank
}

/// 2.5 Binary matrix rank test (32x32 matrices).
///
/// Random binary matrices have full rank with p ≈ 0.2888, rank 31 with
/// p ≈ 0.5776, lower with p ≈ 0.1336; structure in the stream skews this.
pub fn matrix_rank(bits: &[u8]) -> TestResult {
    const P_FULL: f64 = 0.2888;
    const P_M1: f64 = 0.5776;
    const P_LO: f64 = 0.1336;
    let per_matrix = 32 * 32;
    let n_mat = bits.len() / per_matrix;
    assert!(n_mat >= 4, "need >= 4096 bits");
    let mut counts = [0f64; 3]; // full, full-1, lower
    for m in 0..n_mat {
        let chunk = &bits[m * per_matrix..(m + 1) * per_matrix];
        let mut rows = [0u32; 32];
        for (r, row) in rows.iter_mut().enumerate() {
            for c in 0..32 {
                *row = (*row << 1) | chunk[r * 32 + c] as u32;
            }
        }
        match gf2_rank32(&mut rows) {
            32 => counts[0] += 1.0,
            31 => counts[1] += 1.0,
            _ => counts[2] += 1.0,
        }
    }
    let n = n_mat as f64;
    let expect = [n * P_FULL, n * P_M1, n * P_LO];
    let chi2: f64 = counts
        .iter()
        .zip(&expect)
        .map(|(c, e)| (c - e) * (c - e) / e)
        .sum();
    result("matrix_rank", igamc(1.0, chi2 / 2.0))
}

/// Run the whole battery with SP800-22 default parameters.
pub fn run_battery(bits: &[u8]) -> Vec<TestResult> {
    let mut out = vec![
        frequency(bits),
        block_frequency(bits, 128),
        runs(bits),
        longest_run(bits),
        cusum(bits, false),
        cusum(bits, true),
        approximate_entropy(bits, 8),
        spectral(bits),
    ];
    if bits.len() >= 4 * 1024 {
        out.push(matrix_rank(bits));
    }
    let (s1, s2) = serial(bits, 8);
    out.push(s1);
    out.push(s2);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::{BitSource, ChaoticLightSource, Xoshiro256pp};

    fn prng_bits(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256pp::new(seed);
        let mut bits = Vec::with_capacity(n);
        while bits.len() < n {
            let w = rng.next_u64();
            for i in 0..64 {
                bits.push(((w >> i) & 1) as u8);
            }
        }
        bits.truncate(n);
        bits
    }

    #[test]
    fn sp800_22_example_frequency() {
        // SP800-22 §2.1.8 worked example: epsilon = 1100100100001111110110101010001000
        // gives P-value = 0.109599 (n = 100 example uses different data; this
        // is the n = 10 example extended; use the documented 100-bit example).
        let eps = "11001001000011111101101010100010001000010110100011\
                   00001000110100110001001100011001100010100010111000";
        let bits: Vec<u8> = eps
            .chars()
            .filter(|c| !c.is_whitespace())
            .map(|c| c as u8 - b'0')
            .collect();
        let r = frequency(&bits);
        assert!((r.p_value - 0.109599).abs() < 1e-4, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_runs() {
        // §2.3.8 example: 100-bit pi expansion, P-value = 0.500798
        let eps = "11001001000011111101101010100010001000010110100011\
                   00001000110100110001001100011001100010100010111000";
        let bits: Vec<u8> = eps
            .chars()
            .filter(|c| !c.is_whitespace())
            .map(|c| c as u8 - b'0')
            .collect();
        let r = runs(&bits);
        assert!((r.p_value - 0.500798).abs() < 1e-4, "p {}", r.p_value);
    }

    #[test]
    fn sp800_22_example_cusum() {
        // §2.13.8 example: same 100-bit stream, forward P-value = 0.219194
        let eps = "11001001000011111101101010100010001000010110100011\
                   00001000110100110001001100011001100010100010111000";
        let bits: Vec<u8> = eps
            .chars()
            .filter(|c| !c.is_whitespace())
            .map(|c| c as u8 - b'0')
            .collect();
        let r = cusum(&bits, false);
        assert!((r.p_value - 0.219194).abs() < 1e-3, "p {}", r.p_value);
    }

    #[test]
    fn good_prng_passes_battery() {
        let bits = prng_bits(100_000, 42);
        for r in run_battery(&bits) {
            assert!(r.pass, "{} failed: p = {}", r.name, r.p_value);
        }
    }

    #[test]
    fn chaotic_source_passes_battery() {
        // the paper's claim, checked against the simulated ASE source
        let mut src = ChaoticLightSource::with_defaults(2024);
        let bits = src.extract_bits(100.0, 100_000);
        for r in run_battery(&bits) {
            assert!(r.pass, "{} failed: p = {}", r.name, r.p_value);
        }
    }

    #[test]
    fn spectral_passes_prng_fails_periodic() {
        let bits = prng_bits(65_536, 21);
        assert!(spectral(&bits).pass, "p = {}", spectral(&bits).p_value);
        // strong periodic component
        let periodic: Vec<u8> = (0..65_536).map(|i| ((i / 4) % 2) as u8).collect();
        assert!(!spectral(&periodic).pass);
    }

    #[test]
    fn matrix_rank_passes_prng_fails_lowrank() {
        let bits = prng_bits(64 * 1024, 22);
        let r = matrix_rank(&bits);
        assert!(r.pass, "p = {}", r.p_value);
        // rank-1 matrices: every row identical
        let mut low = Vec::with_capacity(64 * 1024);
        let mut rng = Xoshiro256pp::new(23);
        while low.len() < 64 * 1024 {
            let row: Vec<u8> = (0..32).map(|_| u8::from(rng.next_f64() < 0.5)).collect();
            for _ in 0..32 {
                low.extend_from_slice(&row);
            }
        }
        assert!(!matrix_rank(&low).pass);
    }

    #[test]
    fn gf2_rank_known_cases() {
        let mut id = [0u32; 32];
        for (i, r) in id.iter_mut().enumerate() {
            *r = 1 << i;
        }
        assert_eq!(gf2_rank32(&mut id.clone()), 32);
        let mut zero = [0u32; 32];
        assert_eq!(gf2_rank32(&mut zero), 0);
        let mut two = [0u32; 32];
        two[0] = 0b1011;
        two[1] = 0b0101;
        two[2] = 0b1110; // = row0 ^ row1
        assert_eq!(gf2_rank32(&mut two), 2);
    }

    #[test]
    fn constant_stream_fails() {
        let bits = vec![1u8; 10_000];
        let r = frequency(&bits);
        assert!(!r.pass);
    }

    #[test]
    fn alternating_stream_fails_runs() {
        let bits: Vec<u8> = (0..10_000).map(|i| (i % 2) as u8).collect();
        let r = runs(&bits);
        assert!(!r.pass, "p = {}", r.p_value);
    }

    #[test]
    fn biased_stream_fails_battery() {
        // 60/40 bias must be caught by the monobit test at n = 100k
        let mut rng = Xoshiro256pp::new(7);
        let bits: Vec<u8> = (0..100_000)
            .map(|_| u8::from(rng.next_f64() < 0.6))
            .collect();
        assert!(!frequency(&bits).pass);
    }

    #[test]
    fn periodic_structure_fails_serial_or_apen() {
        // embed an 8-bit periodic pattern with small jitter
        let mut rng = Xoshiro256pp::new(8);
        let pat = [1u8, 0, 1, 1, 0, 0, 1, 0];
        let bits: Vec<u8> = (0..50_000)
            .map(|i| {
                if rng.next_f64() < 0.9 {
                    pat[i % 8]
                } else {
                    u8::from(rng.next_f64() < 0.5)
                }
            })
            .collect();
        let battery = run_battery(&bits);
        assert!(battery.iter().any(|r| !r.pass));
    }
}
