//! xoshiro256++ PRNG (Blackman & Vigna) with SplitMix64 seeding.
//!
//! This is the *digital baseline* RNG: the paper's argument is that photonic
//! entropy removes exactly this component from the probabilistic hot path.
//! The simulator also uses it as the underlying uniform source that drives
//! the physically-shaped (Gamma / Gaussian) photonic noise models.

use super::BitSource;

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — 256-bit state, period 2^256 − 1, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // avoid the all-zero state (probability ~2^-256, but be exact)
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    /// Jump ahead 2^128 steps — gives independent parallel streams.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut t = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    for (ti, si) in t.iter_mut().zip(self.s.iter()) {
                        *ti ^= si;
                    }
                }
                self.raw_next();
            }
        }
        self.s = t;
    }

    /// A forked stream 2^128 steps away (safe for parallel workers).
    pub fn fork(&mut self) -> Self {
        let mut child = self.clone();
        child.jump();
        // advance self too so successive forks differ
        self.jump();
        self.jump();
        child
    }

    #[inline]
    fn raw_next(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

impl BitSource for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.raw_next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let m = sum / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }

    #[test]
    fn jump_decorrelates() {
        let mut a = Xoshiro256pp::new(9);
        let mut b = a.clone();
        b.jump();
        let matches = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(matches, 0);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Xoshiro256pp::new(5);
        let mut c1 = root.fork();
        let mut c2 = root.fork();
        let matches = (0..1000).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(matches, 0);
    }

    #[test]
    fn bit_balance() {
        let mut r = Xoshiro256pp::new(3);
        let ones: u32 = (0..1000).map(|_| r.next_u64().count_ones()).sum();
        let frac = ones as f64 / 64_000.0;
        assert!((frac - 0.5).abs() < 0.01, "ones frac {frac}");
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256pp::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
