//! Entropy substrate: PRNGs, distribution samplers, the chaotic-light
//! source model, and a NIST SP800-22 test battery.
//!
//! The paper's core hardware claim is that amplified spontaneous emission
//! (ASE) in an erbium-doped fiber is a *true* random number generator whose
//! filtered intensity directly realizes Gaussian-programmable stochastic
//! weights (mean = optical power, std = optical bandwidth), removing the
//! pseudo-random-number-generation bottleneck of digital Bayesian inference.
//!
//! This module builds that stack from scratch (the offline crate cache has
//! no `rand`):
//!
//! * [`xoshiro`] — xoshiro256++ PRNG + SplitMix64 seeding (the *digital
//!   baseline* the paper compares against, and the simulator's noise base),
//! * [`gaussian`] — Box–Muller / polar-method standard normal sampler,
//! * [`gamma`] — Marsaglia–Tsang Gamma sampler (filtered thermal light has
//!   Gamma-distributed intensity with `M = B·T + 1` degrees of freedom),
//! * [`chaotic`] — the ASE chaotic-light source model used by the photonic
//!   machine simulator and as the serving-time noise provider,
//! * [`nist`] — seven tests from NIST SP800-22 (the paper cites passing
//!   this battery), runnable over any bit stream.

pub mod chaotic;
pub mod gamma;
pub mod gaussian;
pub mod nist;
pub mod xoshiro;

pub use chaotic::ChaoticLightSource;
pub use xoshiro::Xoshiro256pp;

/// Common interface for anything that yields uniform 64-bit words.
pub trait BitSource {
    fn next_u64(&mut self) -> u64;

    /// Uniform in [0, 1) with 53-bit resolution.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_f64() * n as f64) as usize % n
    }
}
