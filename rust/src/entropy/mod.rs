//! Entropy substrate: PRNGs, distribution samplers, the chaotic-light
//! source model, and a NIST SP800-22 test battery.
//!
//! The paper's core hardware claim is that amplified spontaneous emission
//! (ASE) in an erbium-doped fiber is a *true* random number generator whose
//! filtered intensity directly realizes Gaussian-programmable stochastic
//! weights (mean = optical power, std = optical bandwidth), removing the
//! pseudo-random-number-generation bottleneck of digital Bayesian inference.
//!
//! This module builds that stack from scratch (the offline crate cache has
//! no `rand`):
//!
//! * [`xoshiro`] — xoshiro256++ PRNG + SplitMix64 seeding (the *digital
//!   baseline* the paper compares against, and the simulator's noise base),
//! * [`gaussian`] — Box–Muller / polar-method standard normal sampler,
//! * [`gamma`] — Marsaglia–Tsang Gamma sampler (filtered thermal light has
//!   Gamma-distributed intensity with `M = B·T + 1` degrees of freedom),
//! * [`chaotic`] — the ASE chaotic-light source model used by the photonic
//!   machine simulator and as the serving-time noise provider,
//! * [`nist`] — seven tests from NIST SP800-22 (the paper cites passing
//!   this battery), runnable over any bit stream,
//! * [`pipeline`] — the decoupled entropy pipeline: free-running producer
//!   threads filling SPSC block rings (the paper's source/detector split),
//!   with a bitwise-equivalent synchronous fallback,
//! * [`health`] — the online entropy-health monitor: duty-cycled taps on
//!   producer blocks feed the hardened NIST battery plus min-entropy and
//!   serial-correlation estimators into per-(shard, stream) scorecards.

pub mod chaotic;
pub mod gamma;
pub mod gaussian;
pub mod health;
pub mod nist;
pub mod pipeline;
pub mod xoshiro;

pub use chaotic::ChaoticLightSource;
pub use health::{HealthConfig, HealthEvent, Monitor, Scorecard};
pub use pipeline::{PipelineOptions, PrefetchMode};
pub use xoshiro::Xoshiro256pp;

/// Common interface for anything that yields uniform 64-bit words.
pub trait BitSource {
    fn next_u64(&mut self) -> u64;

    /// Uniform in [0, 1) with 53-bit resolution.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) via Lemire's widening-multiply method
    /// (Lemire 2019, "Fast Random Integer Generation in an Interval").
    ///
    /// `x * n >> 64` maps a uniform 64-bit word onto `[0, n)` with each
    /// value hit either `floor(2^64/n)` or `ceil(2^64/n)` times; rejecting
    /// the `2^64 mod n` low-fragment draws makes the output exactly
    /// uniform.  The rejection branch is taken with probability `< n/2^64`
    /// — essentially never for the small `n` used here — and the common
    /// path is one multiply, versus the old float-multiply-then-mod which
    /// was both slower and measurably biased.
    fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut m = u128::from(self.next_u64()) * u128::from(n);
        let mut lo = m as u64;
        if lo < n {
            // 2^64 mod n, computed without overflow
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = u128::from(self.next_u64()) * u128::from(n);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A deterministic word source for exercising the rejection branch.
    struct Fixed {
        vals: Vec<u64>,
        i: usize,
    }

    impl BitSource for Fixed {
        fn next_u64(&mut self) -> u64 {
            let v = self.vals[self.i % self.vals.len()];
            self.i += 1;
            v
        }
    }

    #[test]
    fn next_below_is_uniform_chi_square() {
        let mut rng = Xoshiro256pp::new(0xD1CE);
        let n = 10usize;
        let draws = 100_000usize;
        let mut counts = [0u64; 10];
        for _ in 0..draws {
            let v = rng.next_below(n);
            assert!(v < n);
            counts[v] += 1;
        }
        // chi-square against uniform: 9 dof, p = 0.001 critical value 27.88
        let expect = draws as f64 / n as f64;
        let chi2: f64 = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - expect;
                d * d / expect
            })
            .sum();
        assert!(chi2 < 27.88, "chi2 {chi2}: counts {counts:?}");
    }

    #[test]
    fn next_below_covers_full_range_for_large_n() {
        // the old float path had only 53 bits of resolution and could never
        // produce some values for n near 2^63; the widening multiply can.
        let mut rng = Xoshiro256pp::new(7);
        let n = (1usize << 62) + 12345;
        for _ in 0..1000 {
            assert!(rng.next_below(n) < n);
        }
    }

    #[test]
    fn next_below_one_is_always_zero() {
        let mut rng = Xoshiro256pp::new(1);
        for _ in 0..100 {
            assert_eq!(rng.next_below(1), 0);
        }
    }

    #[test]
    fn rejection_loop_discards_biased_fragment() {
        // n = 3: 2^64 mod 3 = 1, so exactly one word (x = 0, whose product
        // fragment is 0 < 1) is rejected and everything else is accepted
        let t = 3u64.wrapping_neg() % 3; // 2^64 mod 3
        assert_eq!(t, 1);
        // first word: lo = 0 < t -> rejected; second word accepted
        let mut src = Fixed { vals: vec![0, u64::MAX], i: 0 };
        let v = src.next_below(3);
        assert_eq!(v, 2); // u64::MAX * 3 >> 64 = 2
        assert_eq!(src.i, 2, "exactly one rejection retry");
    }

    #[test]
    fn matches_direct_widening_multiply_when_no_rejection() {
        // for words whose low product fragment >= n, the result must be
        // exactly (x * n) >> 64
        let mut rng = Xoshiro256pp::new(99);
        for _ in 0..1000 {
            let x = rng.next_u64();
            let n = 1000u64;
            let lo = (u128::from(x) * u128::from(n)) as u64;
            if lo >= n {
                let mut src = Fixed { vals: vec![x], i: 0 };
                let want = (u128::from(x) * u128::from(n) >> 64) as usize;
                assert_eq!(src.next_below(1000), want);
            }
        }
    }
}
