//! Standard normal sampling (polar Box–Muller with caching).

use super::BitSource;

/// Gaussian sampler wrapping any uniform [`BitSource`].
///
/// Uses the Marsaglia polar method; the spare deviate is cached so the cost
/// amortizes to ~one uniform pair per two normals.
#[derive(Debug, Clone)]
pub struct Gaussian {
    spare: Option<f64>,
}

impl Default for Gaussian {
    fn default() -> Self {
        Self::new()
    }
}

impl Gaussian {
    pub fn new() -> Self {
        Self { spare: None }
    }

    /// One standard normal deviate.
    pub fn sample<R: BitSource>(&mut self, rng: &mut R) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fill a slice with i.i.d. standard normals (f32).
    pub fn fill_f32<R: BitSource>(&mut self, rng: &mut R, out: &mut [f32]) {
        for slot in out {
            *slot = self.sample(rng) as f32;
        }
    }

    /// Fill a slice with i.i.d. standard normals at full f64 precision —
    /// the bulk variant of [`Self::sample`], drawing identical values in
    /// identical order.  Hot paths fill one plane of draws up front instead
    /// of calling `sample` per output symbol.
    pub fn fill_f64<R: BitSource>(&mut self, rng: &mut R, out: &mut [f64]) {
        for slot in out {
            *slot = self.sample(rng);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::Xoshiro256pp;
    use crate::util::mathstat::Welford;

    #[test]
    fn moments_match_standard_normal() {
        let mut rng = Xoshiro256pp::new(1);
        let mut g = Gaussian::new();
        let mut w = Welford::new();
        let mut third = 0.0;
        let n = 200_000;
        for _ in 0..n {
            let x = g.sample(&mut rng);
            w.push(x);
            third += x * x * x;
        }
        assert!(w.mean().abs() < 0.01, "mean {}", w.mean());
        assert!((w.std() - 1.0).abs() < 0.01, "std {}", w.std());
        assert!((third / n as f64).abs() < 0.05, "skew-ish {}", third / n as f64);
    }

    #[test]
    fn tail_mass_reasonable() {
        let mut rng = Xoshiro256pp::new(2);
        let mut g = Gaussian::new();
        let n = 100_000;
        let beyond2 = (0..n).filter(|_| g.sample(&mut rng).abs() > 2.0).count();
        let frac = beyond2 as f64 / n as f64;
        // P(|Z| > 2) = 0.0455
        assert!((frac - 0.0455).abs() < 0.005, "frac {frac}");
    }

    #[test]
    fn fill_writes_every_slot() {
        let mut rng = Xoshiro256pp::new(3);
        let mut g = Gaussian::new();
        let mut buf = vec![0.0f32; 1001];
        g.fill_f32(&mut rng, &mut buf);
        // probability of an exact 0.0 is negligible
        assert!(buf.iter().all(|&x| x != 0.0));
    }

    #[test]
    fn fill_f64_matches_scalar_stream() {
        let mut rng_a = Xoshiro256pp::new(17);
        let mut g_a = Gaussian::new();
        let mut bulk = vec![0.0f64; 257];
        g_a.fill_f64(&mut rng_a, &mut bulk);

        let mut rng_b = Xoshiro256pp::new(17);
        let mut g_b = Gaussian::new();
        for (i, &v) in bulk.iter().enumerate() {
            assert_eq!(v, g_b.sample(&mut rng_b), "draw {i}");
        }
    }
}
