//! Stop-rule evaluation state: per-input hysteresis across chunk checks.

use super::accum::AccumStats;
use super::StopRule;

/// Why sampling stopped for one input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopReason {
    /// A fixed rule spent its whole budget in one round.
    FixedBudget,
    /// The max budget ran out before any rule fired.
    BudgetExhausted,
    /// `ConfidenceGap`: the argmax margin held above target.
    GapResolved,
    /// `UncertaintyResolved`: MI settled below `mi_low` (epistemically
    /// resolved — accept / flag-ambiguous territory).
    UncertaintyLow,
    /// `UncertaintyResolved`: MI settled above `mi_high` (clearly
    /// out-of-domain — further sampling cannot rescue the input).
    UncertaintyHigh,
}

impl StopReason {
    pub fn name(&self) -> &'static str {
        match self {
            StopReason::FixedBudget => "fixed",
            StopReason::BudgetExhausted => "budget",
            StopReason::GapResolved => "gap",
            StopReason::UncertaintyLow => "mi-low",
            StopReason::UncertaintyHigh => "mi-high",
        }
    }
}

/// The decision-aware outcome of one input's sampling loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Verdict {
    /// Stochastic passes actually spent on this input.
    pub samples_used: usize,
    pub reason: StopReason,
}

/// Which side of the MI band a check landed on (hysteresis bookkeeping).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MiSide {
    Low,
    High,
}

/// Per-input evaluation state for one request: consecutive-hit counters and
/// the previously observed argmax.  Deterministic — a pure function of the
/// sequence of [`AccumStats`] it has seen.
#[derive(Debug, Clone, Default)]
pub struct StopState {
    hits: usize,
    last_top: Option<usize>,
    last_side: Option<MiSide>,
}

impl StopState {
    /// Evaluate `rule` against the running stats after a chunk.  `used` is
    /// the samples folded into the accumulator; `min` is the floor below
    /// which no adaptive rule may fire.  Returns the stop reason once the
    /// rule's criterion has held for its `stable` consecutive checks.
    pub fn update(
        &mut self,
        rule: &StopRule,
        stats: &AccumStats,
        used: usize,
        min: usize,
    ) -> Option<StopReason> {
        let fired = match rule {
            StopRule::Fixed(_) => None,
            StopRule::ConfidenceGap { target_gap, stable } => {
                let same_top = self.last_top.map_or(true, |t| t == stats.top);
                if stats.gap >= *target_gap && same_top {
                    self.hits += 1;
                } else {
                    self.hits = 0;
                }
                self.last_top = Some(stats.top);
                (self.hits >= (*stable).max(1)).then_some(StopReason::GapResolved)
            }
            StopRule::UncertaintyResolved {
                mi_low,
                mi_high,
                stable,
            } => {
                let side = if stats.mi <= *mi_low {
                    Some(MiSide::Low)
                } else if stats.mi >= *mi_high {
                    Some(MiSide::High)
                } else {
                    None
                };
                match side {
                    Some(s) if self.last_side == Some(s) || self.last_side.is_none() => {
                        self.hits += 1
                    }
                    Some(_) => self.hits = 1, // switched sides: restart
                    None => self.hits = 0,
                }
                self.last_side = side;
                (side.is_some() && self.hits >= (*stable).max(1)).then(|| match side {
                    Some(MiSide::Low) => StopReason::UncertaintyLow,
                    _ => StopReason::UncertaintyHigh,
                })
            }
        };
        fired.filter(|_| used >= min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(top: usize, gap: f64, mi: f64) -> AccumStats {
        AccumStats {
            n: 4,
            top,
            top_prob: 0.5 + gap / 2.0,
            gap,
            shannon: mi + 0.1,
            softmax: 0.1,
            mi,
        }
    }

    #[test]
    fn fixed_never_fires_early() {
        let rule = StopRule::Fixed(10);
        let mut st = StopState::default();
        for used in 1..100 {
            assert_eq!(st.update(&rule, &stats(0, 1.0, 0.0), used, 1), None);
        }
    }

    #[test]
    fn gap_rule_needs_stability() {
        let rule = StopRule::ConfidenceGap {
            target_gap: 0.5,
            stable: 2,
        };
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(3, 0.8, 0.0), 4, 2), None, "1st hit");
        assert_eq!(
            st.update(&rule, &stats(3, 0.8, 0.0), 6, 2),
            Some(StopReason::GapResolved),
            "2nd consecutive hit fires"
        );
    }

    #[test]
    fn gap_rule_resets_on_argmax_flip_or_collapse() {
        let rule = StopRule::ConfidenceGap {
            target_gap: 0.5,
            stable: 2,
        };
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(3, 0.8, 0.0), 2, 1), None);
        // argmax flips: streak restarts even though the gap is wide
        assert_eq!(st.update(&rule, &stats(1, 0.9, 0.0), 4, 1), None);
        // gap collapses: streak resets to zero
        assert_eq!(st.update(&rule, &stats(1, 0.1, 0.0), 6, 1), None);
        assert_eq!(st.update(&rule, &stats(1, 0.9, 0.0), 8, 1), None);
        assert_eq!(
            st.update(&rule, &stats(1, 0.9, 0.0), 10, 1),
            Some(StopReason::GapResolved)
        );
    }

    #[test]
    fn min_samples_gate_holds_back_early_fires() {
        let rule = StopRule::ConfidenceGap {
            target_gap: 0.2,
            stable: 1,
        };
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(0, 0.9, 0.0), 2, 4), None, "below min");
        assert_eq!(
            st.update(&rule, &stats(0, 0.9, 0.0), 4, 4),
            Some(StopReason::GapResolved)
        );
    }

    #[test]
    fn mi_band_hysteresis_both_sides() {
        let rule = StopRule::UncertaintyResolved {
            mi_low: 0.01,
            mi_high: 0.2,
            stable: 2,
        };
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(0, 0.5, 0.005), 2, 1), None);
        assert_eq!(
            st.update(&rule, &stats(0, 0.5, 0.002), 4, 1),
            Some(StopReason::UncertaintyLow)
        );

        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.5), 2, 1), None);
        assert_eq!(
            st.update(&rule, &stats(0, 0.0, 0.4), 4, 1),
            Some(StopReason::UncertaintyHigh)
        );

        // wobbling through the unresolved band resets the streak
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.005), 2, 1), None);
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.1), 4, 1), None, "in band");
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.005), 6, 1), None, "restart");
        assert_eq!(
            st.update(&rule, &stats(0, 0.0, 0.003), 8, 1),
            Some(StopReason::UncertaintyLow)
        );

        // switching sides restarts the streak at one
        let mut st = StopState::default();
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.005), 2, 1), None);
        assert_eq!(st.update(&rule, &stats(0, 0.0, 0.5), 4, 1), None, "side flip");
        assert_eq!(
            st.update(&rule, &stats(0, 0.0, 0.5), 6, 1),
            Some(StopReason::UncertaintyHigh)
        );
    }

    #[test]
    fn reason_names() {
        assert_eq!(StopReason::FixedBudget.name(), "fixed");
        assert_eq!(StopReason::GapResolved.name(), "gap");
        assert_eq!(StopReason::UncertaintyLow.name(), "mi-low");
    }
}
