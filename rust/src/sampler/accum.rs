//! Incremental predictive aggregation over chunked sampling rounds.

use crate::bnn::metrics;
use crate::bnn::Predictive;
use crate::util::mathstat::softmax;

/// Running statistics of an accumulator, evaluated at chunk boundaries to
/// drive stop rules.  Computed in f64 from the running sums — decision
/// inputs only; the reported [`Predictive`] is finalized through the exact
/// one-shot aggregation path.
#[derive(Debug, Clone)]
pub struct AccumStats {
    /// Samples folded in so far.
    pub n: usize,
    /// argmax of the running mean predictive.
    pub top: usize,
    /// Mean posterior mass of the argmax class.
    pub top_prob: f64,
    /// Argmax margin `p(1st) − p(2nd)` of the running mean predictive.
    pub gap: f64,
    /// Running Shannon entropy of the mean predictive (Eq. 1).
    pub shannon: f64,
    /// Running mean per-pass entropy (Eq. 2).
    pub softmax: f64,
    /// Running mutual information `H − SE`, clamped at 0.
    pub mi: f64,
}

/// Folds chunked rounds of per-pass logits into running per-class
/// statistics.  Keeps the per-pass probability rows, so
/// [`PredictiveAccum::into_predictive`] at any budget goes through
/// [`Predictive::from_probs`] — **bitwise equal** to the one-shot
/// [`Predictive::from_batched_logits`] over the same passes.
#[derive(Debug, Clone)]
pub struct PredictiveAccum {
    n_classes: usize,
    rows: Vec<Vec<f32>>,
    /// f64 running sum of per-pass probabilities (stop-rule inputs).
    sum: Vec<f64>,
    /// f64 running sum of per-pass entropies (stop-rule inputs).
    row_entropy_sum: f64,
    frozen: bool,
}

impl PredictiveAccum {
    pub fn new(n_classes: usize) -> Self {
        assert!(n_classes > 0, "need at least one class");
        Self {
            n_classes,
            rows: Vec::new(),
            sum: vec![0.0; n_classes],
            row_entropy_sum: 0.0,
            frozen: false,
        }
    }

    /// Fold one pass's logits in (softmax + running sums).  Must not be
    /// called on a frozen accumulator.
    pub fn push_logits(&mut self, logits: &[f32]) {
        debug_assert!(!self.frozen, "pushed into a frozen accumulator");
        debug_assert_eq!(logits.len(), self.n_classes);
        let row = softmax(logits);
        self.row_entropy_sum += metrics::entropy(&row);
        for (s, &p) in self.sum.iter_mut().zip(&row) {
            *s += p as f64;
        }
        self.rows.push(row);
    }

    /// Samples folded in so far.
    pub fn n(&self) -> usize {
        self.rows.len()
    }

    pub fn n_classes(&self) -> usize {
        self.n_classes
    }

    /// Stop pushing further samples (the stop rule fired); the final
    /// predictive uses exactly the samples seen so far.
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Running statistics for stop-rule evaluation.
    pub fn stats(&self) -> AccumStats {
        let n = self.rows.len();
        assert!(n > 0, "stats on an empty accumulator");
        let inv = 1.0 / n as f64;
        let mut top = 0usize;
        let mut best = f64::NEG_INFINITY;
        let mut second = f64::NEG_INFINITY;
        let mut shannon = 0.0f64;
        for (c, &s) in self.sum.iter().enumerate() {
            let p = s * inv;
            if p > 0.0 {
                shannon -= p * p.ln();
            }
            if p > best {
                second = best;
                best = p;
                top = c;
            } else if p > second {
                second = p;
            }
        }
        if !second.is_finite() {
            second = 0.0; // single-class banks
        }
        let se = self.row_entropy_sum * inv;
        AccumStats {
            n,
            top,
            top_prob: best,
            gap: best - second,
            shannon,
            softmax: se,
            mi: (shannon - se).max(0.0),
        }
    }

    /// Finalize into the reported [`Predictive`] — the same
    /// [`Predictive::from_probs`] aggregation the one-shot engine path
    /// uses, over exactly the accumulated rows.
    pub fn into_predictive(self) -> Predictive {
        Predictive::from_probs(self.rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn passes(n: usize, nc: usize, seed: u64) -> Vec<Vec<f32>> {
        // deterministic pseudo-logits batches: pass p holds `images * nc`
        let mut v = Vec::new();
        let mut s = seed;
        for _ in 0..n {
            let row: Vec<f32> = (0..nc * 3)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    ((s >> 33) as f32 / (1u64 << 31) as f32) * 4.0 - 2.0
                })
                .collect();
            v.push(row);
        }
        v
    }

    #[test]
    fn incremental_matches_one_shot_bitwise() {
        let batched = passes(10, 4, 99);
        for image in 0..3 {
            let mut acc = PredictiveAccum::new(4);
            for p in &batched {
                acc.push_logits(&p[image * 4..(image + 1) * 4]);
            }
            let a = acc.into_predictive();
            let b = Predictive::from_batched_logits(&batched, image, 4);
            assert_eq!(a.probs, b.probs, "image {image}");
            assert_eq!(a.mean_probs, b.mean_probs, "image {image}");
            assert_eq!(a.predicted, b.predicted);
            assert!(a.shannon_entropy == b.shannon_entropy);
            assert!(a.softmax_entropy == b.softmax_entropy);
            assert!(a.mutual_information == b.mutual_information);
            assert!(a.agreement == b.agreement);
        }
    }

    #[test]
    fn stats_track_running_mean() {
        let mut acc = PredictiveAccum::new(3);
        for _ in 0..5 {
            acc.push_logits(&[4.0, 0.0, 0.0]);
        }
        let s = acc.stats();
        assert_eq!(s.n, 5);
        assert_eq!(s.top, 0);
        assert!(s.top_prob > 0.9);
        assert!(s.gap > 0.85);
        assert!(s.mi < 1e-9, "identical passes carry no epistemic signal");

        // disagreement raises MI
        let mut acc = PredictiveAccum::new(3);
        for i in 0..6 {
            let mut l = [0.0f32; 3];
            l[i % 3] = 6.0;
            acc.push_logits(&l);
        }
        let s = acc.stats();
        assert!(s.mi > 0.5, "mi {}", s.mi);
        assert!(s.gap < 0.1);
    }

    #[test]
    fn stats_agree_with_reference_metrics() {
        let batched = passes(8, 5, 7);
        let mut acc = PredictiveAccum::new(5);
        for p in &batched {
            acc.push_logits(&p[0..5]);
        }
        let s = acc.stats();
        let p = acc.into_predictive();
        // f64 running stats vs the f32-mean reference: equal to float noise
        assert!((s.shannon - p.shannon_entropy).abs() < 1e-5);
        assert!((s.softmax - p.softmax_entropy).abs() < 1e-5);
        assert!((s.mi - p.mutual_information).abs() < 1e-5);
        assert_eq!(s.top, p.predicted);
    }

    #[test]
    fn freeze_is_sticky() {
        let mut acc = PredictiveAccum::new(2);
        acc.push_logits(&[1.0, 0.0]);
        assert!(!acc.is_frozen());
        acc.freeze();
        assert!(acc.is_frozen());
        assert_eq!(acc.n(), 1);
        let p = acc.into_predictive();
        assert_eq!(p.n_samples(), 1);
    }
}
