//! Shared synthetic-classifier harness for the adaptive sampler's bench
//! (`paper_tables -- adaptive`) and integration tests
//! (`rust/tests/adaptive_sampling.rs`).  One copy, so the algorithm the
//! bench measures is exactly the one the tests validate.
//!
//! The "model" is a depthwise readout over a [`ProbConvBackend`]: logit
//! `c` is the mean of channel `c`'s conv outputs.  A *decisive* input
//! lights one channel against one dominant kernel (the posterior gap
//! resolves within a few samples); an *ambiguous* input excites every
//! channel equally and faintly (the gap never opens, so adaptive rules
//! run to the max budget).
//!
//! Not a public API — `#[doc(hidden)]` support code.

use crate::backend::{ProbConvBackend, SamplePlan};
use crate::photonics::TapTarget;

use super::{ChunkSchedule, PredictiveAccum, RequestBudget, SamplerConfig, StopRule, StopState};

/// Synthetic activation maps are `HW x HW` pixels per channel.
pub const HW: usize = 5;

/// One dominant kernel (channel 0), the rest near-zero: a decisive input
/// on channel 0 produces a wide, stable posterior gap.
pub fn decisive_kernels(channels: usize) -> Vec<Vec<TapTarget>> {
    let mut k = vec![vec![TapTarget { mu: 0.05, sigma: 0.1 }; 9]; channels];
    k[0] = vec![TapTarget { mu: 0.6, sigma: 0.15 }; 9];
    k
}

/// Input that lights channel 0 and leaves the rest near dark.
pub fn decisive_input(channels: usize) -> Vec<f32> {
    let item = channels * HW * HW;
    (0..item)
        .map(|i| if i < HW * HW { 0.8 } else { 0.02 })
        .collect()
}

/// Input exciting every channel equally and faintly — no decisive argmax.
pub fn ambiguous_input(channels: usize) -> Vec<f32> {
    vec![0.03f32; channels * HW * HW]
}

/// The confidence-gap configuration both the bench and the tests use.
pub fn gap_config(max_samples: usize) -> SamplerConfig {
    SamplerConfig {
        rule: StopRule::ConfidenceGap {
            target_gap: 0.5,
            stable: 2,
        },
        min_samples: 2,
        max_samples,
        chunk: 2,
    }
}

/// The engine's adaptive round loop, minus PJRT: chunked `sample_conv`
/// rounds, per-pass mean-of-channel logits into a [`PredictiveAccum`],
/// stop checks at every chunk boundary.  Returns
/// `(samples_used, mean_probs)`.
pub fn classify_synthetic(
    be: &mut dyn ProbConvBackend,
    scfg: &SamplerConfig,
    align: usize,
    channels: usize,
    max_n: usize,
    x: &[f32],
) -> (usize, Vec<f32>) {
    let hw = HW * HW;
    let item = channels * hw;
    let resolved = scfg.resolve(max_n, &RequestBudget::default()).unwrap();
    let mut acc = PredictiveAccum::new(channels);
    let mut st = StopState::default();
    let mut sched = ChunkSchedule::new(&resolved, align);
    let mut out = vec![0.0f32; max_n * item];
    while let Some(chunk) = sched.next_chunk() {
        let plan = SamplePlan::new(chunk, 1, channels, HW, HW);
        be.sample_conv(&plan, x, &mut out[..chunk * item]).unwrap();
        for s in 0..chunk {
            let logits: Vec<f32> = (0..channels)
                .map(|c| {
                    out[s * item + c * hw..s * item + (c + 1) * hw].iter().sum::<f32>()
                        / hw as f32
                })
                .collect();
            acc.push_logits(&logits);
        }
        let stats = acc.stats();
        if st
            .update(&resolved.rule, &stats, acc.n(), resolved.min)
            .is_some()
        {
            break;
        }
    }
    let used = acc.n();
    (used, acc.into_predictive().mean_probs)
}
