//! Adaptive sequential sampling: anytime inference with early stopping.
//!
//! The paper's economic claim is that the photonic machine *minimizes the
//! cost of sampling* (37.5 ps per probabilistic convolution).  A fixed
//! `n_samples` budget squanders that: an easy in-domain image pays the same
//! N stochastic passes as an ambiguous or out-of-domain one, even though
//! its decision is statistically resolved after two or three.  This
//! subsystem draws predictive samples in **chunks** and stops as soon as a
//! pluggable [`StopRule`] declares the decision resolved:
//!
//! * [`accum::PredictiveAccum`] folds chunked rounds of per-pass logits
//!   into running per-class statistics.  Run to the full budget it is
//!   **bitwise equal** to the one-shot
//!   [`crate::bnn::Predictive::from_batched_logits`] aggregation — it keeps
//!   the same softmax rows and finalizes through the same
//!   `Predictive::from_probs`; the f64 running stats only drive stop
//!   decisions, never the reported output.
//! * [`StopRule`] — `Fixed(n)` (the compatibility default), `ConfidenceGap`
//!   (argmax posterior-gap stability), `UncertaintyResolved` (MI band
//!   crossing with hysteresis) — all clamped by `min_samples` /
//!   `max_samples` and evaluated at chunk boundaries by [`stop::StopState`].
//! * [`schedule::ChunkSchedule`] slices the budget into rounds.  `Fixed`
//!   emits **one** full-budget chunk, so the fixed path issues exactly the
//!   single batched `sample_conv` call it always has — bitwise identical
//!   per `(seed, threads, prefetch)`.  Adaptive rules emit chunks rounded
//!   up to the worker-shard count; the backends' shard entropy streams
//!   persist across calls, so a fixed `(seed, threads, prefetch)` and chunk
//!   sequence replays bit-identically, and at `threads = 1` a chunked run
//!   to full budget is bitwise identical to the one-shot call.
//!
//! [`RequestBudget`] carries per-request overrides (`max_samples`,
//! `target_confidence`) from the wire protocol / CLI; [`BudgetError`] is
//! the typed rejection for hostile or nonsensical budgets (`n == 0`,
//! `min > max`, non-finite confidence) at the protocol boundary.

pub mod accum;
pub mod schedule;
pub mod stop;
#[doc(hidden)]
pub mod synth;

pub use accum::{AccumStats, PredictiveAccum};
pub use schedule::ChunkSchedule;
pub use stop::{StopReason, StopState, Verdict};

/// When to stop drawing predictive samples for one input.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopRule {
    /// Always draw exactly `n` samples in one round (`0` = inherit the
    /// engine's `n_samples`).  The compatibility default: classify outputs
    /// are bitwise identical to the pre-sampler engine.
    Fixed(usize),
    /// Stop once the running mean posterior's argmax margin
    /// `p(1st) − p(2nd)` is at least `target_gap` with an unchanged argmax
    /// for `stable` consecutive chunk checks.
    ConfidenceGap { target_gap: f64, stable: usize },
    /// Stop once the running mutual information leaves the unresolved band:
    /// `MI <= mi_low` (epistemically resolved — accept or flag-ambiguous
    /// territory) or `MI >= mi_high` (clearly out-of-domain — reject
    /// territory), sustained for `stable` consecutive chunk checks
    /// (hysteresis against MI estimates wobbling across a threshold).
    UncertaintyResolved {
        mi_low: f64,
        mi_high: f64,
        stable: usize,
    },
}

impl StopRule {
    /// Default adaptive rule: MI band around the paper's OOD operating
    /// points (0.0185 blood / 0.00308 digits), two-round hysteresis.
    pub fn uncertainty_default() -> Self {
        StopRule::UncertaintyResolved {
            mi_low: 0.002,
            mi_high: 0.08,
            stable: 2,
        }
    }

    /// Build a [`StopRule::ConfidenceGap`] from a requested posterior mass
    /// `c` for the predicted class: the argmax margin a top posterior of
    /// `c` guarantees in the binary worst case is `2c − 1`.
    pub fn confidence_target(c: f64) -> Result<Self, BudgetError> {
        if !c.is_finite() {
            return Err(BudgetError::NonFiniteConfidence(c));
        }
        if !(0.5..1.0).contains(&c) {
            return Err(BudgetError::ConfidenceOutOfRange(c));
        }
        Ok(StopRule::ConfidenceGap {
            target_gap: 2.0 * c - 1.0,
            stable: 2,
        })
    }

    /// Whether this rule can ever stop before the max budget.
    pub fn is_adaptive(&self) -> bool {
        !matches!(self, StopRule::Fixed(_))
    }

    pub fn name(&self) -> &'static str {
        match self {
            StopRule::Fixed(_) => "fixed",
            StopRule::ConfidenceGap { .. } => "confidence-gap",
            StopRule::UncertaintyResolved { .. } => "uncertainty",
        }
    }
}

/// Typed rejection for invalid sample budgets — raised at the protocol /
/// CLI boundary instead of panicking (or NaN-poisoning a stop decision)
/// deep inside the engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BudgetError {
    /// A zero sample budget (`n_samples`, `max_samples`, or a `Fixed(0)`
    /// rule with no engine default to inherit).
    ZeroSamples,
    /// `min_samples` exceeds `max_samples`.
    MinAboveMax { min: usize, max: usize },
    /// `target_confidence` is NaN or infinite.
    NonFiniteConfidence(f64),
    /// `target_confidence` outside `[0.5, 1)` — below 0.5 stops
    /// immediately, 1.0 can never be reached by a finite posterior.
    ConfidenceOutOfRange(f64),
    /// An inverted MI band (`mi_low > mi_high`): every MI value would land
    /// on the "low" side first and resolve instantly as settled.
    InvertedMiBand { low: f64, high: f64 },
    /// `mi_low` / `mi_high` is NaN or infinite.
    NonFiniteMiBand(f64),
}

impl std::fmt::Display for BudgetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BudgetError::ZeroSamples => write!(f, "sample budget must be >= 1"),
            BudgetError::MinAboveMax { min, max } => {
                write!(f, "min_samples {min} exceeds max_samples {max}")
            }
            BudgetError::NonFiniteConfidence(c) => {
                write!(f, "target_confidence must be finite, got {c}")
            }
            BudgetError::ConfidenceOutOfRange(c) => {
                write!(f, "target_confidence must be in [0.5, 1), got {c}")
            }
            BudgetError::InvertedMiBand { low, high } => {
                write!(f, "inverted MI band: mi_low {low} > mi_high {high}")
            }
            BudgetError::NonFiniteMiBand(v) => {
                write!(f, "mi_low/mi_high must be finite, got {v}")
            }
        }
    }
}

impl std::error::Error for BudgetError {}

/// Per-request budget overrides, carried by the wire protocol
/// (`max_samples` / `target_confidence` request fields) and the CLI.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RequestBudget {
    /// Cap this request's sample budget below the engine's (never raises
    /// it — a client cannot buy more compute than the engine configured).
    pub max_samples: Option<usize>,
    /// Ask for early stopping at this posterior mass on the predicted
    /// class (switches the rule to [`StopRule::ConfidenceGap`]).
    pub target_confidence: Option<f64>,
}

impl RequestBudget {
    /// Validate the raw request fields.
    pub fn validate(&self) -> Result<(), BudgetError> {
        if self.max_samples == Some(0) {
            return Err(BudgetError::ZeroSamples);
        }
        if let Some(c) = self.target_confidence {
            // constructing the rule performs the range checks
            StopRule::confidence_target(c)?;
        }
        Ok(())
    }

    pub fn is_default(&self) -> bool {
        self.max_samples.is_none() && self.target_confidence.is_none()
    }
}

/// Engine-level sampler configuration (`[sampler]` in a serving TOML,
/// `--adaptive` / `--min-samples` / `--max-samples` /
/// `--target-confidence` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplerConfig {
    pub rule: StopRule,
    /// Never stop an adaptive rule before this many samples.
    pub min_samples: usize,
    /// Hard per-request budget; `0` = inherit the engine's `n_samples`.
    pub max_samples: usize,
    /// Samples drawn per round between stop checks; `0` = auto
    /// (`max(2, worker shards)`).
    pub chunk: usize,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self {
            rule: StopRule::Fixed(0),
            min_samples: 2,
            max_samples: 0,
            chunk: 0,
        }
    }
}

impl SamplerConfig {
    /// The compatibility configuration: always draw exactly `n` samples.
    pub fn fixed(n: usize) -> Self {
        Self {
            rule: StopRule::Fixed(n),
            ..Self::default()
        }
    }

    /// Adaptive configuration with the default MI-band rule.
    pub fn adaptive() -> Self {
        Self {
            rule: StopRule::uncertainty_default(),
            ..Self::default()
        }
    }

    /// Validate the *configured* fields (CLI / config-file boundary).
    /// `min > max` is only an error when both are explicit — `max = 0`
    /// inherits the engine budget, which is checked at resolve time.
    pub fn validate(&self) -> Result<(), BudgetError> {
        if self.max_samples != 0 && self.min_samples > self.max_samples {
            return Err(BudgetError::MinAboveMax {
                min: self.min_samples,
                max: self.max_samples,
            });
        }
        if let StopRule::ConfidenceGap { target_gap, .. } = self.rule {
            if !target_gap.is_finite() {
                return Err(BudgetError::NonFiniteConfidence(target_gap));
            }
        }
        if let StopRule::UncertaintyResolved { mi_low, mi_high, .. } = self.rule {
            if !mi_low.is_finite() || !mi_high.is_finite() {
                return Err(BudgetError::NonFiniteMiBand(if mi_low.is_finite() {
                    mi_high
                } else {
                    mi_low
                }));
            }
            if mi_low > mi_high {
                return Err(BudgetError::InvertedMiBand {
                    low: mi_low,
                    high: mi_high,
                });
            }
        }
        Ok(())
    }

    /// Resolve this configuration against the engine's per-request pass
    /// budget and one request's overrides into a concrete sampling plan.
    pub fn resolve(
        &self,
        engine_samples: usize,
        req: &RequestBudget,
    ) -> Result<ResolvedSampler, BudgetError> {
        self.validate()?;
        req.validate()?;
        // a configured max is an explicit operator choice; a *request* can
        // only lower the effective budget, never raise it
        let mut max = if self.max_samples == 0 {
            engine_samples
        } else {
            self.max_samples
        };
        if let Some(m) = req.max_samples {
            max = max.min(m);
        }
        if max == 0 {
            return Err(BudgetError::ZeroSamples);
        }
        let mut rule = match req.target_confidence {
            Some(c) => {
                // the request picks the rule; the operator's configured
                // hysteresis (stable consecutive checks) still applies
                let configured_stable = match self.rule {
                    StopRule::ConfidenceGap { stable, .. }
                    | StopRule::UncertaintyResolved { stable, .. } => stable,
                    StopRule::Fixed(_) => 2,
                };
                match StopRule::confidence_target(c)? {
                    StopRule::ConfidenceGap { target_gap, .. } => StopRule::ConfidenceGap {
                        target_gap,
                        stable: configured_stable,
                    },
                    r => r,
                }
            }
            None => self.rule,
        };
        if let StopRule::Fixed(n) = rule {
            let n = if n == 0 { max } else { n.min(max) };
            rule = StopRule::Fixed(n);
        }
        let min = self.min_samples.clamp(1, max);
        // an adaptive rule that cannot check before the budget is spent
        // collapses to the fixed single round (e.g. deterministic backends
        // where the engine budget is 1)
        if rule.is_adaptive() && min >= max {
            rule = StopRule::Fixed(max);
        }
        let chunk = if self.chunk == 0 { 2 } else { self.chunk };
        Ok(ResolvedSampler {
            rule,
            min,
            max,
            chunk,
        })
    }
}

/// A fully-resolved per-request sampling plan (all zeros/inherits applied).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolvedSampler {
    pub rule: StopRule,
    pub min: usize,
    pub max: usize,
    pub chunk: usize,
}

impl ResolvedSampler {
    /// Fixed rules run as one batched round — the legacy engine path.
    pub fn single_round(&self) -> bool {
        !self.rule.is_adaptive()
    }

    /// The sample count of the single fixed round.
    pub fn fixed_samples(&self) -> usize {
        match self.rule {
            StopRule::Fixed(n) => n,
            _ => self.max,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_resolves_to_engine_fixed_budget() {
        let r = SamplerConfig::default()
            .resolve(10, &RequestBudget::default())
            .unwrap();
        assert_eq!(r.rule, StopRule::Fixed(10));
        assert!(r.single_round());
        assert_eq!(r.fixed_samples(), 10);
    }

    #[test]
    fn request_budget_caps_but_never_raises() {
        let cfg = SamplerConfig::default();
        let r = cfg
            .resolve(
                10,
                &RequestBudget {
                    max_samples: Some(4),
                    target_confidence: None,
                },
            )
            .unwrap();
        assert_eq!(r.fixed_samples(), 4);
        let r = cfg
            .resolve(
                10,
                &RequestBudget {
                    max_samples: Some(40),
                    target_confidence: None,
                },
            )
            .unwrap();
        assert_eq!(r.fixed_samples(), 10, "requests cannot raise the budget");
    }

    #[test]
    fn target_confidence_switches_to_gap_rule() {
        let r = SamplerConfig::default()
            .resolve(
                10,
                &RequestBudget {
                    max_samples: None,
                    target_confidence: Some(0.9),
                },
            )
            .unwrap();
        match r.rule {
            StopRule::ConfidenceGap { target_gap, .. } => {
                assert!((target_gap - 0.8).abs() < 1e-12)
            }
            other => panic!("{other:?}"),
        }
        assert!(!r.single_round());
    }

    #[test]
    fn typed_rejections_at_the_boundary() {
        assert_eq!(
            RequestBudget {
                max_samples: Some(0),
                target_confidence: None,
            }
            .validate(),
            Err(BudgetError::ZeroSamples)
        );
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let e = RequestBudget {
                max_samples: None,
                target_confidence: Some(bad),
            }
            .validate()
            .unwrap_err();
            assert!(matches!(e, BudgetError::NonFiniteConfidence(_)), "{bad}");
        }
        for bad in [0.2, 0.49, 1.0, 1.5] {
            let e = StopRule::confidence_target(bad).unwrap_err();
            assert!(matches!(e, BudgetError::ConfidenceOutOfRange(_)), "{bad}");
        }
        let cfg = SamplerConfig {
            min_samples: 8,
            max_samples: 4,
            ..SamplerConfig::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(BudgetError::MinAboveMax { min: 8, max: 4 })
        );
        assert!(cfg.resolve(10, &RequestBudget::default()).is_err());
        // errors render as human-readable typed messages
        assert!(BudgetError::ZeroSamples.to_string().contains(">= 1"));
    }

    #[test]
    fn inverted_mi_band_rejected() {
        let cfg = SamplerConfig {
            rule: StopRule::UncertaintyResolved {
                mi_low: 0.08,
                mi_high: 0.002,
                stable: 2,
            },
            ..SamplerConfig::default()
        };
        assert_eq!(
            cfg.validate(),
            Err(BudgetError::InvertedMiBand {
                low: 0.08,
                high: 0.002
            })
        );
        assert!(cfg.resolve(10, &RequestBudget::default()).is_err());
        // degenerate-but-ordered band (low == high) stays legal
        let eq = SamplerConfig {
            rule: StopRule::UncertaintyResolved {
                mi_low: 0.01,
                mi_high: 0.01,
                stable: 2,
            },
            ..SamplerConfig::default()
        };
        assert!(eq.validate().is_ok());
    }

    #[test]
    fn request_confidence_inherits_configured_hysteresis() {
        let cfg = SamplerConfig {
            rule: StopRule::UncertaintyResolved {
                mi_low: 0.002,
                mi_high: 0.08,
                stable: 5,
            },
            ..SamplerConfig::default()
        };
        let r = cfg
            .resolve(
                10,
                &RequestBudget {
                    max_samples: None,
                    target_confidence: Some(0.8),
                },
            )
            .unwrap();
        match r.rule {
            StopRule::ConfidenceGap { stable, .. } => assert_eq!(stable, 5),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn adaptive_collapses_on_deterministic_budget() {
        // mean-field backends expose a 1-pass budget: adaptive rules must
        // collapse to Fixed(1) instead of scheduling unreachable rounds
        let r = SamplerConfig::adaptive()
            .resolve(1, &RequestBudget::default())
            .unwrap();
        assert_eq!(r.rule, StopRule::Fixed(1));
        assert!(r.single_round());
    }

    #[test]
    fn min_clamped_into_budget() {
        let cfg = SamplerConfig {
            rule: StopRule::uncertainty_default(),
            min_samples: 6,
            max_samples: 0,
            chunk: 0,
        };
        let r = cfg
            .resolve(
                10,
                &RequestBudget {
                    max_samples: Some(3),
                    target_confidence: None,
                },
            )
            .unwrap();
        // request cap under the configured min: clamp (and collapse to
        // fixed), don't reject — the conflict came from the client cap
        assert_eq!(r.min, 3);
        assert_eq!(r.rule, StopRule::Fixed(3));
    }

    #[test]
    fn rule_names_and_adaptivity() {
        assert_eq!(StopRule::Fixed(3).name(), "fixed");
        assert!(!StopRule::Fixed(3).is_adaptive());
        assert!(StopRule::uncertainty_default().is_adaptive());
        assert_eq!(
            StopRule::confidence_target(0.75).unwrap().name(),
            "confidence-gap"
        );
    }
}
