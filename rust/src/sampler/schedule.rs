//! Chunked budget slicing over the sharded sampling backends.

use super::ResolvedSampler;

/// Slices a resolved per-request budget into sampling rounds.
///
/// * **Fixed** rules emit exactly one chunk of the whole budget — the
///   legacy engine path, issuing the identical single batched
///   `sample_conv` call, so outputs stay bitwise identical to the
///   pre-sampler engine for every `(seed, threads, prefetch)`.
/// * **Adaptive** rules emit a first chunk of at least `min` samples, then
///   `chunk`-sized rounds until `max` is spent.  Chunk sizes are rounded
///   **up to a multiple of the worker-shard count** (`align`): every shard
///   advances its persistent entropy stream by whole samples each round,
///   keeping shard loads equal and the chunk partition a pure function of
///   the chunk sequence.  Because the backends' shard streams persist
///   across `sample_conv` calls, a fixed `(seed, threads, prefetch)` +
///   chunk sequence replays bit-identically — and at `threads = 1` a
///   chunked run to full budget is bitwise identical to the one-shot call
///   (the single stream consumes the same grid rows in the same order).
///   The final chunk truncates to the remaining budget regardless of
///   alignment.
#[derive(Debug, Clone)]
pub struct ChunkSchedule {
    remaining: usize,
    first: usize,
    step: usize,
    started: bool,
}

impl ChunkSchedule {
    pub fn new(r: &ResolvedSampler, align: usize) -> Self {
        let align = align.max(1);
        if r.single_round() {
            let n = r.fixed_samples();
            return Self {
                remaining: n,
                first: n,
                step: n.max(1),
                started: false,
            };
        }
        Self {
            remaining: r.max,
            first: align_up(r.min, align).min(r.max),
            step: align_up(r.chunk.max(1), align),
            started: false,
        }
    }

    /// Samples to draw in the next round; `None` when the budget is spent.
    /// Callers break out of the loop early once every input is resolved.
    pub fn next_chunk(&mut self) -> Option<usize> {
        if self.remaining == 0 {
            return None;
        }
        let want = if self.started { self.step } else { self.first };
        self.started = true;
        let c = want.min(self.remaining);
        self.remaining -= c;
        Some(c)
    }

    /// Budget not yet scheduled.
    pub fn remaining(&self) -> usize {
        self.remaining
    }
}

fn align_up(x: usize, align: usize) -> usize {
    x.div_ceil(align) * align
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{RequestBudget, SamplerConfig, StopRule};

    fn resolved(rule: StopRule, min: usize, max: usize, chunk: usize) -> ResolvedSampler {
        ResolvedSampler {
            rule,
            min,
            max,
            chunk,
        }
    }

    fn drain(mut s: ChunkSchedule) -> Vec<usize> {
        let mut v = Vec::new();
        while let Some(c) = s.next_chunk() {
            v.push(c);
        }
        v
    }

    #[test]
    fn fixed_is_one_full_chunk() {
        let r = SamplerConfig::default()
            .resolve(10, &RequestBudget::default())
            .unwrap();
        for align in [1, 3, 4] {
            assert_eq!(drain(ChunkSchedule::new(&r, align)), vec![10]);
        }
    }

    #[test]
    fn adaptive_chunks_cover_budget_exactly() {
        let r = resolved(StopRule::uncertainty_default(), 2, 10, 2);
        assert_eq!(drain(ChunkSchedule::new(&r, 1)), vec![2, 2, 2, 2, 2]);
        // align 4: min 2 rounds up to 4, steps of 4, final truncated to 2
        assert_eq!(drain(ChunkSchedule::new(&r, 4)), vec![4, 4, 2]);
        // align 3: 3 + 3 + 3 + 1
        assert_eq!(drain(ChunkSchedule::new(&r, 3)), vec![3, 3, 3, 1]);
        for align in [1, 2, 3, 4, 8] {
            let chunks = drain(ChunkSchedule::new(&r, align));
            assert_eq!(chunks.iter().sum::<usize>(), 10, "align {align}");
            assert!(chunks[0] >= 2.min(10), "first covers min");
            for c in &chunks[..chunks.len() - 1] {
                assert_eq!(c % align, 0, "non-final chunks shard-aligned");
            }
        }
    }

    #[test]
    fn min_dominates_first_chunk() {
        let r = resolved(StopRule::uncertainty_default(), 5, 12, 2);
        assert_eq!(drain(ChunkSchedule::new(&r, 2)), vec![6, 2, 2, 2]);
    }

    #[test]
    fn remaining_tracks_budget() {
        let r = resolved(StopRule::uncertainty_default(), 2, 6, 2);
        let mut s = ChunkSchedule::new(&r, 1);
        assert_eq!(s.remaining(), 6);
        assert_eq!(s.next_chunk(), Some(2));
        assert_eq!(s.remaining(), 4);
    }

    #[test]
    fn degenerate_single_sample_budget() {
        let r = resolved(StopRule::Fixed(1), 1, 1, 2);
        assert_eq!(drain(ChunkSchedule::new(&r, 8)), vec![1]);
    }
}
