//! Rust-side synthetic workload generation (bench inputs, probe activations).
//!
//! These generators exist so the benches and the serving load generator do
//! not depend on the Python-generated datasets being present: random
//! activation maps with post-ReLU statistics, random kernels in the
//! machine's native range, and Poisson arrival processes for the serving
//! benchmarks.

use crate::entropy::{BitSource, Xoshiro256pp};
use crate::photonics::TapTarget;

/// Random non-negative activation map in [0, scale) — the statistics that
/// reach the photonic stage after ReLU + DAC quantization.
pub fn random_activations(rng: &mut Xoshiro256pp, len: usize, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| {
            // sparse-ish, post-ReLU-looking: ~40 % zeros
            if rng.next_f32() < 0.4 {
                0.0
            } else {
                rng.next_f32() * scale
            }
        })
        .collect()
}

/// Random 9-tap kernel targets within the machine's realizable range.
pub fn random_kernel(rng: &mut Xoshiro256pp) -> Vec<TapTarget> {
    (0..9)
        .map(|_| {
            let mu = rng.next_f32() * 2.0 - 1.0;
            let rel = 0.4 + 0.55 * rng.next_f32();
            TapTarget {
                mu,
                sigma: (mu.abs() * rel).max(0.05),
            }
        })
        .collect()
}

/// Exponential inter-arrival times (Poisson process) for load generation.
pub fn poisson_arrivals_us(rng: &mut Xoshiro256pp, rate_per_sec: f64, n: usize) -> Vec<f64> {
    let mean_us = 1e6 / rate_per_sec;
    (0..n)
        .map(|_| -mean_us * (1.0 - rng.next_f64()).ln())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::mean;

    #[test]
    fn activations_nonnegative_and_bounded() {
        let mut rng = Xoshiro256pp::new(1);
        let a = random_activations(&mut rng, 10_000, 4.0);
        assert!(a.iter().all(|&x| (0.0..4.0).contains(&x)));
        let zeros = a.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 3000 && zeros < 5000);
    }

    #[test]
    fn kernels_have_nine_realizable_taps() {
        let mut rng = Xoshiro256pp::new(2);
        for _ in 0..100 {
            let k = random_kernel(&mut rng);
            assert_eq!(k.len(), 9);
            for t in k {
                assert!(t.sigma > 0.0);
                assert!(t.mu.abs() <= 1.0);
            }
        }
    }

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Xoshiro256pp::new(3);
        let gaps = poisson_arrivals_us(&mut rng, 1000.0, 50_000);
        let m = mean(&gaps);
        assert!((m - 1000.0).abs() < 20.0, "mean gap {m} us");
    }
}
