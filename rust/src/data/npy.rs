//! Minimal NumPy `.npy` reader/writer (format version 1.0).
//!
//! Supports the dtypes the pipeline uses: `|u1` (uint8 images), `<i4`/`<i8`
//! (labels), `<f4` (float tensors).  C-order only.

use std::fs;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Element type of a loaded array.
#[derive(Debug, Clone, PartialEq)]
pub enum NpyData {
    U8(Vec<u8>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    F32(Vec<f32>),
}

/// A loaded `.npy` array.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// View as f32, converting integer types (u8 stays 0..255 — callers
    /// normalize images themselves).
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            NpyData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::F32(v) => v.clone(),
        }
    }

    /// View labels as i64 regardless of on-disk width.
    pub fn to_i64(&self) -> Vec<i64> {
        match &self.data {
            NpyData::U8(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::I64(v) => v.clone(),
            NpyData::F32(v) => v.iter().map(|&x| x as i64).collect(),
        }
    }
}

const MAGIC: &[u8] = b"\x93NUMPY";

/// Read a `.npy` file.
pub fn read(path: &Path) -> Result<NpyArray> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Parse `.npy` bytes.
pub fn parse(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        bail!("not a .npy file");
    }
    let major = bytes[6];
    let (header_len, header_start) = match major {
        1 => (
            u16::from_le_bytes([bytes[8], bytes[9]]) as usize,
            10usize,
        ),
        2 | 3 => (
            u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize,
            12usize,
        ),
        v => bail!("unsupported .npy version {v}"),
    };
    let header = std::str::from_utf8(&bytes[header_start..header_start + header_len])
        .map_err(|e| anyhow!("bad header utf8: {e}"))?;
    let descr = dict_value(header, "descr").ok_or_else(|| anyhow!("no descr in header"))?;
    let fortran = dict_value(header, "fortran_order")
        .map(|v| v.contains("True"))
        .unwrap_or(false);
    if fortran {
        bail!("fortran_order arrays unsupported");
    }
    let shape_str = dict_value(header, "shape").ok_or_else(|| anyhow!("no shape in header"))?;
    let shape: Vec<usize> = shape_str
        .trim_matches(|c| c == '(' || c == ')')
        .split(',')
        .filter_map(|s| s.trim().parse::<usize>().ok())
        .collect();
    let n: usize = shape.iter().product();
    let body = &bytes[header_start + header_len..];
    let descr = descr.trim_matches(|c| c == '\'' || c == '"');
    let data = match descr {
        "|u1" | "u1" => {
            ensure_len(body, n, 1)?;
            NpyData::U8(body[..n].to_vec())
        }
        "<i4" => {
            ensure_len(body, n, 4)?;
            NpyData::I32(
                body[..4 * n]
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        "<i8" => {
            ensure_len(body, n, 8)?;
            NpyData::I64(
                body[..8 * n]
                    .chunks_exact(8)
                    .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            )
        }
        "<f4" => {
            ensure_len(body, n, 4)?;
            NpyData::F32(
                body[..4 * n]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            )
        }
        other => bail!("unsupported dtype {other}"),
    };
    Ok(NpyArray { shape, data })
}

fn ensure_len(body: &[u8], n: usize, width: usize) -> Result<()> {
    if body.len() < n * width {
        bail!("truncated body: want {} bytes, have {}", n * width, body.len());
    }
    Ok(())
}

/// Extract `'key': value` from the python-dict header (string values keep
/// their quotes; tuple values keep parens).
fn dict_value<'a>(header: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("'{key}':");
    let start = header.find(&pat)? + pat.len();
    let rest = header[start..].trim_start();
    if rest.starts_with('(') {
        let end = rest.find(')')?;
        Some(&rest[..=end])
    } else {
        let end = rest.find(',').unwrap_or(rest.len());
        Some(rest[..end].trim())
    }
}

/// Write an f32 array as `.npy` v1.0.
pub fn write_f32(path: &Path, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic+version+len+header is a multiple of 64, ending in \n
    let base = MAGIC.len() + 2 + 2;
    let total = (base + header.len() + 1 + 63) / 64 * 64;
    while base + header.len() + 1 < total {
        header.push(' ');
    }
    header.push('\n');
    let mut f = fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&[1u8, 0u8])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for x in data {
        f.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("pbm_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.npy");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write_f32(&p, &[2, 3, 4], &data).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![2, 3, 4]);
        assert_eq!(arr.to_f32(), data);
    }

    #[test]
    fn parse_handwritten_u8() {
        // construct a v1.0 header by hand
        let header = "{'descr': '|u1', 'fortran_order': False, 'shape': (3,), }          \n";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[7, 8, 9]);
        let arr = parse(&bytes).unwrap();
        assert_eq!(arr.shape, vec![3]);
        assert_eq!(arr.data, NpyData::U8(vec![7, 8, 9]));
        assert_eq!(arr.to_i64(), vec![7, 8, 9]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse(b"not numpy at all").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let header = "{'descr': '<f4', 'fortran_order': False, 'shape': (10,), }        \n";
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1, 0]);
        bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&[0u8; 8]); // only 2 floats of 10
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn scalar_and_1d_shapes() {
        let data = vec![1.0f32, 2.0, 3.0];
        let dir = std::env::temp_dir().join("pbm_npy_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("one_d.npy");
        write_f32(&p, &[3], &data).unwrap();
        let arr = read(&p).unwrap();
        assert_eq!(arr.shape, vec![3]);
    }
}
