//! Typed dataset handles over the `.npy` artifacts written by
//! `python/compile/datasets.py`, with normalization and minibatching.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::npy;
use crate::entropy::{BitSource, Xoshiro256pp};

/// The evaluation roles the paper's datasets play.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// In-domain train/test data (digits, blood ID classes).
    InDomain,
    /// Aleatoric probe (Ambiguous-MNIST analogue).
    Aleatoric,
    /// Epistemic probe (Fashion-MNIST analogue / erythroblasts).
    Epistemic,
}

/// An image-classification dataset in (N, C, H, W) layout, pixels in [0, 1].
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub kind: DatasetKind,
    pub images: Vec<f32>,
    pub labels: Vec<i64>,
    pub n: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
}

impl Dataset {
    /// Load `<stem>_x.npy` / `<stem>_y.npy` from the artifacts data dir.
    pub fn load(data_dir: &Path, stem: &str, kind: DatasetKind) -> Result<Self> {
        let x_path: PathBuf = data_dir.join(format!("{stem}_x.npy"));
        let y_path: PathBuf = data_dir.join(format!("{stem}_y.npy"));
        let x = npy::read(&x_path).context("loading images")?;
        let y = npy::read(&y_path).context("loading labels")?;
        if x.shape.len() != 4 {
            bail!("expected (N, C, H, W) images, got {:?}", x.shape);
        }
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        // labels may be (N,) or (N, 2) for ambiguous pairs; use first column
        let labels_raw = y.to_i64();
        let labels: Vec<i64> = if y.shape.len() == 2 {
            labels_raw.chunks(y.shape[1]).map(|c| c[0]).collect()
        } else {
            labels_raw
        };
        if labels.len() != n {
            bail!("label count {} != image count {}", labels.len(), n);
        }
        // normalize u8 -> [0, 1]; f32 data passes through
        let images = match &x.data {
            npy::NpyData::U8(v) => v.iter().map(|&p| p as f32 / 255.0).collect(),
            _ => x.to_f32(),
        };
        Ok(Self {
            name: stem.to_string(),
            kind,
            images,
            labels,
            n,
            channels: c,
            height: h,
            width: w,
        })
    }

    /// Pixels of sample `i` (length C*H*W).
    pub fn image(&self, i: usize) -> &[f32] {
        let sz = self.channels * self.height * self.width;
        &self.images[i * sz..(i + 1) * sz]
    }

    pub fn image_size(&self) -> usize {
        self.channels * self.height * self.width
    }

    /// Gather a batch of samples by index into a contiguous buffer.
    pub fn gather(&self, idxs: &[usize], out_x: &mut Vec<f32>, out_y: &mut Vec<i32>) {
        out_x.clear();
        out_y.clear();
        for &i in idxs {
            out_x.extend_from_slice(self.image(i));
            out_y.push(self.labels[i] as i32);
        }
    }

    /// An epoch's worth of shuffled batch index lists (last partial batch
    /// dropped — the train-step HLO has a fixed batch dimension).
    pub fn shuffled_batches(&self, batch: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut idx: Vec<usize> = (0..self.n).collect();
        let mut rng = Xoshiro256pp::new(seed);
        // Fisher–Yates
        for i in (1..idx.len()).rev() {
            let j = rng.next_below(i + 1);
            idx.swap(i, j);
        }
        idx.chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| c.to_vec())
            .collect()
    }

    /// Number of distinct labels (assumes labels 0..k-1 present).
    pub fn num_classes(&self) -> usize {
        self.labels.iter().map(|&l| l as usize + 1).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::npy::write_f32;

    fn tmp_dataset(n: usize, c: usize) -> (std::path::PathBuf, String) {
        let dir = std::env::temp_dir().join(format!("pbm_ds_test_{n}_{c}"));
        std::fs::create_dir_all(&dir).unwrap();
        let imgs: Vec<f32> = (0..n * c * 4 * 4).map(|i| (i % 17) as f32 / 16.0).collect();
        write_f32(&dir.join("toy_x.npy"), &[n, c, 4, 4], &imgs).unwrap();
        let labels: Vec<f32> = (0..n).map(|i| (i % 3) as f32).collect();
        write_f32(&dir.join("toy_y.npy"), &[n], &labels).unwrap();
        (dir, "toy".to_string())
    }

    #[test]
    fn loads_and_indexes() {
        let (dir, stem) = tmp_dataset(10, 3);
        let ds = Dataset::load(&dir, &stem, DatasetKind::InDomain).unwrap();
        assert_eq!(ds.n, 10);
        assert_eq!(ds.image_size(), 48);
        assert_eq!(ds.image(2).len(), 48);
        assert_eq!(ds.num_classes(), 3);
    }

    #[test]
    fn gather_builds_contiguous_batch() {
        let (dir, stem) = tmp_dataset(6, 1);
        let ds = Dataset::load(&dir, &stem, DatasetKind::InDomain).unwrap();
        let mut x = Vec::new();
        let mut y = Vec::new();
        ds.gather(&[0, 3, 5], &mut x, &mut y);
        assert_eq!(x.len(), 3 * 16);
        assert_eq!(y, vec![0, 0, 2]);
        assert_eq!(&x[16..32], ds.image(3));
    }

    #[test]
    fn shuffled_batches_cover_and_fix_size() {
        let (dir, stem) = tmp_dataset(25, 1);
        let ds = Dataset::load(&dir, &stem, DatasetKind::InDomain).unwrap();
        let batches = ds.shuffled_batches(8, 1);
        assert_eq!(batches.len(), 3); // 25 / 8 = 3 full batches
        let mut seen: Vec<usize> = batches.concat();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 24);
        // deterministic per seed
        assert_eq!(ds.shuffled_batches(8, 1), batches);
        assert_ne!(ds.shuffled_batches(8, 2), batches);
    }
}
