//! Dataset substrate: `.npy` interchange with the Python build path, typed
//! dataset handles, batching, and Rust-side synthetic workload generation.

pub mod dataset;
pub mod npy;
pub mod synth;

pub use dataset::{Dataset, DatasetKind};
