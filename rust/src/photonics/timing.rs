//! Architecture timing constants and the paper's headline-number derivations.
//!
//! Every number in the paper's abstract is a *derived* quantity of the
//! architecture constants below; `headline()` recomputes them so the
//! `paper_tables -- headline` bench can print paper-vs-derived side by side.

/// Sample rate of the DAC and ADC (samples/s). Paper: 80 GSPS.
pub const SAMPLE_RATE_GSPS: f64 = 80.0;
/// Resolution of DAC and ADC in bits. Paper: 8 bit.
pub const CONVERTER_BITS: u32 = 8;
/// Samples per encoded vector component. Paper: 3.
pub const SAMPLES_PER_SYMBOL: f64 = 3.0;
/// Number of spectral weight channels. Paper: 9.
pub const NUM_CHANNELS: usize = 9;
/// Channel grid center (THz). Paper: 194 THz.
pub const CENTER_THZ: f64 = 194.0;
/// Channel spacing (GHz). Paper: 403 GHz.
pub const SPACING_GHZ: f64 = 403.0;
/// Grating dispersion (ps/THz). Paper: −93.1.
pub const DISPERSION_PS_PER_THZ: f64 = -93.1;
/// Chirped grating length (cm). Paper: 5.68 cm.
pub const GRATING_LENGTH_CM: f64 = 5.68;
/// Group index of the SiN spiral waveguide (typical thin-film Si3N4).
pub const GROUP_INDEX: f64 = 2.1;

/// Derived headline metrics.
#[derive(Debug, Clone)]
pub struct Headline {
    /// Symbol period = one probabilistic convolution (ps). Paper: 37.5.
    pub symbol_period_ps: f64,
    /// Probabilistic convolutions per second. Paper: ~26.7 G.
    pub convolutions_per_sec: f64,
    /// Probabilistic MACs per second (9 taps per convolution).
    pub macs_per_sec: f64,
    /// Digital interface bandwidth, DAC + ADC (Tbit/s). Paper: 1.28.
    pub interface_tbit_per_sec: f64,
    /// Per-channel delay step from the grating (ps); should equal the symbol
    /// period so adjacent channels shift by exactly one symbol.
    pub channel_delay_step_ps: f64,
    /// Grating propagation latency (ns); the "sub-100 ns" claim.
    pub grating_latency_ns: f64,
}

/// Recompute every abstract number from the constants.
pub fn headline() -> Headline {
    let symbol_period_ps = SAMPLES_PER_SYMBOL / SAMPLE_RATE_GSPS * 1000.0;
    let convolutions_per_sec = SAMPLE_RATE_GSPS * 1e9 / SAMPLES_PER_SYMBOL;
    let interface = 2.0 * SAMPLE_RATE_GSPS * 1e9 * CONVERTER_BITS as f64 / 1e12;
    let delay_step = DISPERSION_PS_PER_THZ.abs() * SPACING_GHZ / 1000.0;
    let latency_ns = GRATING_LENGTH_CM * 1e-2 * GROUP_INDEX / 2.998e8 * 1e9;
    Headline {
        symbol_period_ps,
        convolutions_per_sec,
        macs_per_sec: convolutions_per_sec * NUM_CHANNELS as f64,
        interface_tbit_per_sec: interface,
        channel_delay_step_ps: delay_step,
        grating_latency_ns: latency_ns,
    }
}

/// Simulated optical clock: tracks how much *optical* time the simulated
/// machine has consumed (symbols processed x symbol period), independent of
/// host wall-clock.
#[derive(Debug, Clone, Default)]
pub struct OpticalClock {
    symbols: u64,
}

impl OpticalClock {
    pub fn advance_symbols(&mut self, n: u64) {
        self.symbols += n;
    }

    pub fn symbols(&self) -> u64 {
        self.symbols
    }

    pub fn elapsed_ps(&self) -> f64 {
        self.symbols as f64 * headline().symbol_period_ps
    }

    pub fn elapsed_ns(&self) -> f64 {
        self.elapsed_ps() / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_matches_paper() {
        let h = headline();
        assert!((h.symbol_period_ps - 37.5).abs() < 1e-9);
        assert!((h.convolutions_per_sec - 26.67e9).abs() < 0.05e9);
        assert!((h.interface_tbit_per_sec - 1.28).abs() < 1e-9);
        // 93.1 ps/THz * 0.403 THz = 37.5 ps -> exactly one symbol per channel
        assert!((h.channel_delay_step_ps - 37.5).abs() < 0.1);
        assert!(h.grating_latency_ns < 100.0, "sub-100 ns claim");
        assert!(h.grating_latency_ns > 0.1);
    }

    #[test]
    fn optical_clock_accumulates() {
        let mut c = OpticalClock::default();
        c.advance_symbols(1000);
        assert!((c.elapsed_ps() - 37_500.0).abs() < 1e-6);
        assert!((c.elapsed_ns() - 37.5).abs() < 1e-9);
    }
}
