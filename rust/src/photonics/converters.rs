//! 8-bit DAC / ADC models (the machine's digital interface).
//!
//! Symmetric signed quantization on a full-scale range, mirroring the
//! `fake_quant8` straight-through kernel of the L2 surrogate exactly: the
//! training-time STE and the serving-time hardware must round identically,
//! or the surrogate would be biased against the machine.

/// Symmetric 8-bit quantizer: `q = clip(round(x/scale*127), -128, 127)`.
#[derive(Debug, Clone, Copy)]
pub struct Quantizer {
    pub scale: f32,
}

impl Quantizer {
    pub fn new(scale: f32) -> Self {
        assert!(scale > 0.0);
        Self { scale }
    }

    /// Quantize to the integer code (-128..=127).
    #[inline]
    pub fn code(&self, x: f32) -> i16 {
        let q = (x / self.scale * 127.0).round();
        q.clamp(-128.0, 127.0) as i16
    }

    /// Quantize and reconstruct (the value the analog domain actually sees).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        self.code(x) as f32 * self.scale / 127.0
    }

    /// Quantization step size.
    pub fn lsb(&self) -> f32 {
        self.scale / 127.0
    }

    /// In-place quantization of a buffer (DAC feeding the EOM).
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_identity_on_grid() {
        let q = Quantizer::new(4.0);
        for code in -128i16..=127 {
            let x = code as f32 * 4.0 / 127.0;
            assert_eq!(q.code(x), code);
            assert!((q.quantize(x) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn clips_out_of_range() {
        let q = Quantizer::new(4.0);
        assert_eq!(q.code(100.0), 127);
        assert_eq!(q.code(-100.0), -128);
    }

    #[test]
    fn error_bounded_by_half_lsb() {
        let q = Quantizer::new(8.0);
        for i in 0..1000 {
            let x = -7.9 + 0.0158 * i as f32;
            assert!((q.quantize(x) - x).abs() <= q.lsb() / 2.0 + 1e-6);
        }
    }

    #[test]
    fn matches_python_fake_quant8() {
        // identical formula as kernels/photonic_conv.py::fake_quant8
        let q = Quantizer::new(4.0);
        let cases = [(0.5f32, 0.503937f32), (-1.234, -1.228346), (3.99, 4.0)];
        for (x, want) in cases {
            assert!((q.quantize(x) - want).abs() < 1e-4, "{x}");
        }
    }
}
