//! Balanced photodetector + receiver-noise model.
//!
//! The detector incoherently sums the time-shifted channel powers; balanced
//! (differential) detection of a plus- and minus-rail realizes signed
//! weights.  Receiver noise lumps thermal noise, shot noise, and residual
//! RIN into a single additive Gaussian term referred to the output, which is
//! then quantized by the 8-bit ADC.

use super::converters::Quantizer;
use crate::entropy::gaussian::Gaussian;
use crate::entropy::Xoshiro256pp;

#[derive(Debug, Clone)]
pub struct Detector {
    adc: Quantizer,
    /// RMS receiver noise referred to the output (same units as the result).
    noise_rms: f32,
    rng: Xoshiro256pp,
    gauss: Gaussian,
}

impl Detector {
    pub fn new(adc_full_scale: f32, noise_rms: f32, seed: u64) -> Self {
        Self {
            adc: Quantizer::new(adc_full_scale),
            noise_rms,
            rng: Xoshiro256pp::new(seed),
            gauss: Gaussian::new(),
        }
    }

    /// Read out one already-summed differential power value: add receiver
    /// noise, then ADC-quantize.
    #[inline]
    pub fn read(&mut self, summed: f32) -> f32 {
        let noisy = summed + self.noise_rms * self.gauss.sample(&mut self.rng) as f32;
        self.adc.quantize(noisy)
    }

    pub fn adc_lsb(&self) -> f32 {
        self.adc.lsb()
    }

    pub fn full_scale(&self) -> f32 {
        self.adc.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::Welford;

    #[test]
    fn noiseless_detector_is_pure_quantizer() {
        let mut d = Detector::new(8.0, 0.0, 1);
        let q = Quantizer::new(8.0);
        for i in 0..100 {
            let x = -7.5 + 0.15 * i as f32;
            assert_eq!(d.read(x), q.quantize(x));
        }
    }

    #[test]
    fn receiver_noise_has_programmed_rms() {
        let mut d = Detector::new(100.0, 0.5, 2);
        let mut w = Welford::new();
        for _ in 0..50_000 {
            w.push(d.read(3.0) as f64);
        }
        assert!((w.mean() - 3.0).abs() < 0.02);
        // total std = receiver noise + ADC quantization noise (lsb^2 / 12)
        let lsb = (100.0f64 / 127.0).powi(2) / 12.0;
        let expect = (0.25 + lsb).sqrt();
        assert!((w.std() - expect).abs() < 0.02, "std {} expect {expect}", w.std());
    }

    #[test]
    fn output_clips_at_full_scale() {
        let mut d = Detector::new(8.0, 0.0, 3);
        assert!(d.read(20.0) <= 8.0);
        assert!(d.read(-20.0) >= -8.1);
    }
}
