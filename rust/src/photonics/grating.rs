//! Waveguide-integrated chirped grating (the frequency→time coupler).
//!
//! The grating's period is swept along a 5.68 cm SiN spiral so each spectral
//! channel reflects at a different depth, inducing a frequency-dependent
//! group delay of −93.1 ps/THz (paper Fig. 2(b,e)).  With a 403 GHz channel
//! grid this shifts adjacent channels by exactly one symbol (37.5 ps), which
//! is what turns the nine WDM channels into the nine taps of a sliding
//! convolution window.

use super::timing;

#[derive(Debug, Clone)]
pub struct ChirpedGrating {
    /// Dispersion slope (ps/THz).
    pub dispersion_ps_per_thz: f64,
    /// Reference frequency (THz) whose delay is taken as zero.
    pub f0_thz: f64,
    /// Per-channel residual delay ripple (ps), a deterministic fabrication
    /// signature (measured once, fixed thereafter).
    ripple_ps: Vec<f64>,
}

impl ChirpedGrating {
    /// Build the paper's grating for an `n_channels` grid.  `ripple_rms_ps`
    /// sets the fabrication-ripple magnitude (0.0 for an ideal device).
    pub fn paper_device(n_channels: usize, ripple_rms_ps: f64, seed: u64) -> Self {
        use crate::entropy::{BitSource, Xoshiro256pp};
        let mut rng = Xoshiro256pp::new(seed);
        let ripple = (0..n_channels)
            .map(|_| (rng.next_f64() * 2.0 - 1.0) * ripple_rms_ps * 1.732)
            .collect();
        Self {
            dispersion_ps_per_thz: timing::DISPERSION_PS_PER_THZ,
            f0_thz: timing::CENTER_THZ,
            ripple_ps: ripple,
        }
    }

    /// Group delay (ps) at an optical frequency, relative to `f0`.
    pub fn delay_ps(&self, f_thz: f64) -> f64 {
        self.dispersion_ps_per_thz * (f_thz - self.f0_thz)
    }

    /// Group delay of channel `k` on the grid (including its ripple).
    pub fn channel_delay_ps(&self, k: usize) -> f64 {
        let f = channel_frequency_thz(k, self.ripple_ps.len());
        self.delay_ps(f) + self.ripple_ps.get(k).copied().unwrap_or(0.0)
    }

    /// Integer symbol shift of channel `k` (the convolution tap index), and
    /// the residual misalignment as a fraction of the symbol period.
    pub fn channel_symbol_shift(&self, k: usize) -> (i64, f64) {
        let t_sym = timing::headline().symbol_period_ps;
        let d = self.channel_delay_ps(k) - self.channel_delay_ps(0);
        let shift = (d / t_sym).round();
        let resid = (d - shift * t_sym) / t_sym;
        (shift as i64, resid)
    }

    /// Tap alignment factor in (0, 1]: eye-closure from residual timing
    /// misalignment (linear model: a symbol sampled `|r|·T` off-center loses
    /// `|r|` of its energy to the neighbor slots).
    pub fn alignment_factor(&self, k: usize) -> f64 {
        let (_, r) = self.channel_symbol_shift(k);
        1.0 - r.abs()
    }

    /// Propagation latency through the spiral (ns).
    pub fn latency_ns(&self) -> f64 {
        timing::headline().grating_latency_ns
    }
}

/// Frequency of channel `k` on the paper's grid (403 GHz spacing around
/// 194 THz), `k = 0..n`.
pub fn channel_frequency_thz(k: usize, n: usize) -> f64 {
    let offset = k as f64 - (n as f64 - 1.0) / 2.0;
    timing::CENTER_THZ + offset * timing::SPACING_GHZ / 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::linfit;

    #[test]
    fn grid_is_centered() {
        let f4 = channel_frequency_thz(4, 9);
        assert!((f4 - 194.0).abs() < 1e-9);
        let spacing = channel_frequency_thz(1, 9) - channel_frequency_thz(0, 9);
        assert!((spacing - 0.403).abs() < 1e-9);
    }

    #[test]
    fn delay_slope_is_dispersion() {
        // the Fig. 2(e) measurement: delay vs channel frequency slope
        let g = ChirpedGrating::paper_device(9, 0.0, 0);
        let f: Vec<f64> = (0..9).map(|k| channel_frequency_thz(k, 9)).collect();
        let d: Vec<f64> = (0..9).map(|k| g.channel_delay_ps(k)).collect();
        let (_a, slope, r2) = linfit(&f, &d);
        assert!((slope - (-93.1)).abs() < 0.01, "slope {slope}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn one_symbol_shift_per_channel() {
        let g = ChirpedGrating::paper_device(9, 0.0, 0);
        for k in 0..9 {
            let (shift, resid) = g.channel_symbol_shift(k);
            // dispersion is negative: higher channel index -> earlier arrival
            assert_eq!(shift, -(k as i64), "channel {k}");
            assert!(resid.abs() < 0.02, "resid {resid}");
        }
    }

    #[test]
    fn ripple_reduces_alignment() {
        let ideal = ChirpedGrating::paper_device(9, 0.0, 1);
        let rough = ChirpedGrating::paper_device(9, 2.0, 1);
        let a_ideal: f64 = (0..9).map(|k| ideal.alignment_factor(k)).sum();
        let a_rough: f64 = (0..9).map(|k| rough.alignment_factor(k)).sum();
        assert!(a_rough < a_ideal);
        for k in 0..9 {
            assert!(rough.alignment_factor(k) > 0.8);
        }
    }

    #[test]
    fn latency_is_sub_100ns() {
        let g = ChirpedGrating::paper_device(9, 0.0, 0);
        assert!(g.latency_ns() < 100.0);
    }
}
