//! Photonic Bayesian machine simulator (the paper's hardware, in software).
//!
//! Faithful functional model of the analog datapath of Fig. 2(a):
//!
//! ```text
//!  ASE chaotic source ──► spectral shaper (9 channels: power = weight mean,
//!        │                  bandwidth = weight std)
//!        ▼
//!  EOM + 8-bit 80 GSPS DAC (input vector time-encoded on all channels,
//!        │                   3 samples per symbol)
//!        ▼
//!  chirped grating (−93.1 ps/THz ⇒ one-symbol delay per 403 GHz channel)
//!        ▼
//!  photodetector (incoherent power sum + receiver noise)
//!        ▼
//!  8-bit 80 GSPS ADC ──► y[t] = Σ_k w_k(t) · x[t−k]
//! ```
//!
//! Negative weights are realized with *differential (balanced) detection*:
//! each tap owns a plus-rail and a minus-rail intensity whose difference is
//! the signed weight (see DESIGN.md substitution table).  Because the rails
//! are chaotic, the tap's mean is programmed by the rail power difference
//! and its standard deviation by the channel bandwidth (speckle degrees of
//! freedom `M = B·T + 1`) plus optional common-mode power.
//!
//! [`timing`] derives the paper's headline numbers (37.5 ps per probabilistic
//! convolution, 26.7 G convolutions/s, 1.28 Tbit/s digital interface,
//! sub-100 ns latency) from the architecture constants, and the machine
//! keeps a simulated optical clock so benches can report both simulated
//! optical throughput and simulator wall-clock throughput.

pub mod converters;
pub mod detector;
pub mod eom;
pub mod grating;
pub mod machine;
pub mod timing;

pub use machine::{FlatTap, KernelProgram, MachineConfig, PhotonicMachine, TapTarget};
