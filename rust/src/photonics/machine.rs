//! The photonic Bayesian machine: composition of source, EOM, grating,
//! detector — programmed with probabilistic weight kernels, streaming
//! convolutions.
//!
//! ## Weight encoding (paper Fig. 1(c) / Fig. S2)
//!
//! Tap `k` is a differential pair of chaotic rails with mean powers
//! `P⁺, P⁻` and shared speckle degrees of freedom `M = B·T + 1`:
//!
//! ```text
//!   w_k(t) = g·a_k·(I⁺_k(t) − I⁻_k(t)),   I± ~ Gamma(M, P±/M)
//!   E[w]   = g·a_k·(P⁺ − P⁻)              (power difference -> mean)
//!   Std[w] = g·a_k·sqrt((P⁺² + P⁻²)/M)    (bandwidth -> std)
//! ```
//!
//! where `g` is the transimpedance gain and `a_k` the grating alignment
//! factor.  Programming inverts these relations; the bandwidth clamp
//! `B ∈ [25, 150] GHz` makes small relative stds unrealizable — the same
//! hardware floor the L2 surrogate's straight-through estimator applies.
//!
//! ## Actuator error and feedback calibration
//!
//! Loading a program into "hardware" applies multiplicative actuator error
//! to the commanded powers/bandwidths (spectral-shaper inaccuracy).  The
//! [`crate::calibration`] loop measures realized weight moments via probe
//! convolutions and iteratively corrects the command — the paper's
//! "iteratively program ... by computing test convolutions and calculating
//! the difference between the target and programmed distributions".

use super::converters::Quantizer;
use super::detector::Detector;
use super::eom::Eom;
use super::grating::ChirpedGrating;
use super::timing::{self, OpticalClock};
use crate::entropy::chaotic::{ChaoticLightSource, SourceConfig};
use crate::entropy::gaussian::Gaussian;
use crate::entropy::Xoshiro256pp;
use crate::exec::scratch::{grow, ScratchArena};

/// Target distribution for one tap (what SVI learned).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TapTarget {
    pub mu: f32,
    pub sigma: f32,
}

/// Commanded + realized analog state of one tap.
#[derive(Debug, Clone)]
pub struct TapProgram {
    /// Commanded plus/minus rail powers and degrees of freedom.
    pub cmd_p_plus: f64,
    pub cmd_p_minus: f64,
    pub cmd_dof: f64,
    /// Realized values after actuator error (what the light actually does).
    real_p_plus: f64,
    real_p_minus: f64,
    real_dof: f64,
    /// Effective gain: transimpedance x grating alignment for this channel.
    pub gain_eff: f64,
}

impl TapProgram {
    /// Expected weight mean of the *realized* program.
    pub fn realized_mu(&self) -> f64 {
        self.gain_eff * (self.real_p_plus - self.real_p_minus)
    }

    /// Expected weight std of the realized program.
    pub fn realized_sigma(&self) -> f64 {
        self.gain_eff
            * ((self.real_p_plus.powi(2) + self.real_p_minus.powi(2)) / self.real_dof).sqrt()
    }

    /// Commanded bandwidth in GHz for a given symbol time.
    pub fn bandwidth_ghz(&self, t_symbol_ps: f64) -> f64 {
        (self.cmd_dof - 1.0) / (t_symbol_ps * 1e-12) / 1e9
    }
}

/// Flattened realized sampling parameters of one tap — the dense cache the
/// conv hot path reads instead of chasing [`TapProgram`] fields.  Plain
/// `Copy` data so parallel worker shards can sample from a shared
/// `&KernelProgram` without synchronization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlatTap {
    pub p_plus: f64,
    pub p_minus: f64,
    pub dof: f64,
    pub gain_eff: f64,
}

/// One programmed 9-tap kernel (one 3x3 depthwise filter).
#[derive(Debug, Clone)]
pub struct KernelProgram {
    pub taps: Vec<TapProgram>,
    /// Realized-parameter cache, rebuilt whenever the taps (re)actuate —
    /// hoists the per-call program-tuple copy out of `conv_patches`.
    flat: Vec<FlatTap>,
}

impl KernelProgram {
    fn from_taps(taps: Vec<TapProgram>) -> Self {
        let mut kp = Self {
            taps,
            flat: Vec::new(),
        };
        kp.rebuild_flat();
        kp
    }

    fn rebuild_flat(&mut self) {
        self.flat.clear();
        self.flat.extend(self.taps.iter().map(|t| FlatTap {
            p_plus: t.real_p_plus,
            p_minus: t.real_p_minus,
            dof: t.real_dof,
            gain_eff: t.gain_eff,
        }));
    }

    /// The dense realized parameters, one entry per tap.
    pub fn flat(&self) -> &[FlatTap] {
        &self.flat
    }
}

/// Machine configuration. Defaults follow the paper's system architecture.
#[derive(Debug, Clone)]
pub struct MachineConfig {
    pub source: SourceConfig,
    /// Transimpedance gain mapping optical power to weight units.
    pub gain: f64,
    /// Total optical power budget per tap (both rails), weight units / gain.
    pub power_budget: f64,
    /// DAC full scale for input activations (must match L2 `SCALE_DAC`).
    pub scale_dac: f32,
    /// ADC full scale for readouts (must match L2 `SCALE_ADC`).
    pub scale_adc: f32,
    /// RMS receiver noise referred to the output.
    pub rx_noise: f32,
    /// EOM extinction ratio in dB.
    pub extinction_db: f32,
    /// Grating fabrication delay ripple RMS (ps).
    pub ripple_rms_ps: f64,
    /// Persistent per-channel actuator bias RMS (spectral-shaper transfer
    /// error: fixed at fabrication, correctable by feedback calibration).
    pub actuator_sigma: f64,
    /// Fresh multiplicative jitter applied on every (re)load (shaper
    /// settling noise: the irreducible floor of the calibration loop).
    pub actuator_jitter: f64,
    pub seed: u64,
}

impl Default for MachineConfig {
    fn default() -> Self {
        Self {
            source: SourceConfig::default(),
            gain: 1.0,
            power_budget: 6.0,
            scale_dac: 4.0,
            scale_adc: 8.0,
            rx_noise: 0.02,
            extinction_db: 30.0,
            ripple_rms_ps: 0.5,
            actuator_sigma: 0.05,
            actuator_jitter: 0.01,
            seed: 7,
        }
    }
}

/// Per-run counters (throughput accounting + telemetry).
#[derive(Debug, Clone, Default)]
pub struct MachineStats {
    pub convolutions: u64,
    pub programs_loaded: u64,
    pub clock: OpticalClock,
}

/// The photonic Bayesian machine simulator.
pub struct PhotonicMachine {
    pub cfg: MachineConfig,
    eom: Eom,
    grating: ChirpedGrating,
    detector: Detector,
    src: ChaoticLightSource,
    actuator_rng: Xoshiro256pp,
    actuator_gauss: Gaussian,
    /// Persistent per-channel actuator biases: (plus-rail, minus-rail, dof)
    /// multiplicative transfer errors, fixed at construction.
    chan_bias: Vec<(f64, f64, f64)>,
    bank: Vec<KernelProgram>,
    /// Reusable hot-path buffers (im2col planes, conv accumulators, bulk
    /// draws) — steady-state convolutions allocate nothing.
    scratch: ScratchArena,
    pub stats: MachineStats,
}

impl PhotonicMachine {
    pub fn new(cfg: MachineConfig) -> Self {
        let eom = Eom::new(cfg.scale_dac, cfg.extinction_db);
        let grating =
            ChirpedGrating::paper_device(cfg.source.channels, cfg.ripple_rms_ps, cfg.seed);
        let detector = Detector::new(cfg.scale_adc, cfg.rx_noise, cfg.seed.wrapping_add(1));
        let src = ChaoticLightSource::new(cfg.source.clone(), cfg.seed.wrapping_add(2));
        let mut rng = Xoshiro256pp::new(cfg.seed.wrapping_add(3));
        let mut gauss = Gaussian::new();
        let chan_bias = (0..cfg.source.channels)
            .map(|_| {
                let mut b = || (1.0 + cfg.actuator_sigma * gauss.sample(&mut rng)).max(0.5);
                (b(), b(), b())
            })
            .collect();
        Self {
            eom,
            grating,
            detector,
            src,
            actuator_rng: rng,
            actuator_gauss: gauss,
            chan_bias,
            bank: Vec::new(),
            scratch: ScratchArena::default(),
            stats: MachineStats::default(),
            cfg,
        }
    }

    pub fn with_defaults(seed: u64) -> Self {
        Self::new(MachineConfig {
            seed,
            ..MachineConfig::default()
        })
    }

    /// Number of taps / spectral channels.
    pub fn num_taps(&self) -> usize {
        self.cfg.source.channels
    }

    // ------------------------------------------------------------------
    // Programming
    // ------------------------------------------------------------------

    /// Physics inversion: compute the commanded program realizing `(mu, sigma)`
    /// as closely as the hardware allows.  Pure — no actuator error.
    pub fn solve_program(&self, k: usize, tgt: TapTarget) -> TapProgram {
        let t_sym = self.cfg.source.t_symbol_ps;
        let m_min = self.cfg.source.dof(self.cfg.source.bw_min_ghz);
        let m_max = self.cfg.source.dof(self.cfg.source.bw_max_ghz);
        let ge = self.cfg.gain * self.grating.alignment_factor(k);
        let mu = tgt.mu as f64;
        let sigma = (tgt.sigma as f64).max(0.0);
        let d = mu.abs() / ge;

        let (m, p_cm) = if sigma <= 1e-9 {
            (m_max, 0.0)
        } else {
            let m_req = if d > 0.0 { (mu / sigma as f64).powi(2) } else { 0.0 };
            if m_req >= m_max {
                (m_max, 0.0) // sigma floor: hardware cannot be this quiet
            } else if m_req <= m_min {
                // boost sigma with common-mode power on both rails
                let s = sigma * m_min.sqrt() / ge;
                let disc = (2.0 * s * s - d * d).max(0.0);
                ((m_min), (disc.sqrt() - d) / 2.0)
            } else {
                (m_req, 0.0)
            }
        };

        let (mut p_plus, mut p_minus) = if mu >= 0.0 {
            (d + p_cm, p_cm)
        } else {
            (p_cm, d + p_cm)
        };
        // power budget clamp (scales mean and std together)
        let tot = p_plus + p_minus;
        if tot > self.cfg.power_budget {
            let r = self.cfg.power_budget / tot;
            p_plus *= r;
            p_minus *= r;
        }
        let _ = t_sym;
        TapProgram {
            cmd_p_plus: p_plus,
            cmd_p_minus: p_minus,
            cmd_dof: m,
            real_p_plus: p_plus,
            real_p_minus: p_minus,
            real_dof: m,
            gain_eff: ge,
        }
    }

    /// Apply actuator error: the spectral shaper's persistent per-channel
    /// transfer bias plus fresh settling jitter.  Called on every (re)load
    /// of a program onto channel `k`.
    fn actuate(&mut self, k: usize, tap: &mut TapProgram) {
        let bias = self.chan_bias[k];
        let mut draw = |base: f64, b: f64| -> f64 {
            let e =
                1.0 + self.cfg.actuator_jitter * self.actuator_gauss.sample(&mut self.actuator_rng);
            (base * b * e).max(0.0)
        };
        tap.real_p_plus = draw(tap.cmd_p_plus, bias.0);
        tap.real_p_minus = draw(tap.cmd_p_minus, bias.1);
        let m_min = self.cfg.source.dof(self.cfg.source.bw_min_ghz);
        let m_max = self.cfg.source.dof(self.cfg.source.bw_max_ghz);
        // dof realization may exceed the nominal bandwidth range slightly via
        // bias; clamp only below (physical positivity), not above, so the
        // calibration loop can actually reach targets near the range edge.
        tap.real_dof = draw(tap.cmd_dof, bias.2).max(m_min * 0.5);
        let _ = m_max;
    }

    /// Program one kernel from targets (open loop) and load it into the
    /// bank; returns its kernel index.
    pub fn load_kernel(&mut self, targets: &[TapTarget]) -> usize {
        assert_eq!(targets.len(), self.num_taps(), "need one target per channel");
        let mut taps: Vec<TapProgram> = targets
            .iter()
            .enumerate()
            .map(|(k, &t)| self.solve_program(k, t))
            .collect();
        for (k, tap) in taps.iter_mut().enumerate() {
            self.actuate(k, tap);
        }
        self.bank.push(KernelProgram::from_taps(taps));
        self.stats.programs_loaded += 1;
        self.bank.len() - 1
    }

    /// Replace the command of kernel `idx` (calibration update) and re-actuate.
    pub fn reprogram_kernel(&mut self, idx: usize, cmds: Vec<(f64, f64, f64)>) {
        let m_min = self.cfg.source.dof(self.cfg.source.bw_min_ghz);
        let m_max = self.cfg.source.dof(self.cfg.source.bw_max_ghz);
        // update commands first, then actuate (borrow discipline)
        {
            let kp = &mut self.bank[idx];
            assert_eq!(cmds.len(), kp.taps.len());
            for (tap, (pp, pm, dof)) in kp.taps.iter_mut().zip(cmds) {
                tap.cmd_p_plus = pp.max(0.0);
                tap.cmd_p_minus = pm.max(0.0);
                tap.cmd_dof = dof.clamp(m_min, m_max);
            }
        }
        let mut taps = std::mem::take(&mut self.bank[idx].taps);
        for (k, tap) in taps.iter_mut().enumerate() {
            self.actuate(k, tap);
        }
        self.bank[idx].taps = taps;
        self.bank[idx].rebuild_flat();
        self.stats.programs_loaded += 1;
    }

    pub fn kernel(&self, idx: usize) -> &KernelProgram {
        &self.bank[idx]
    }

    pub fn bank_len(&self) -> usize {
        self.bank.len()
    }

    pub fn clear_bank(&mut self) {
        self.bank.clear();
    }

    // ------------------------------------------------------------------
    // Sampling + convolution (the hot path)
    // ------------------------------------------------------------------

    /// Draw one instantaneous weight sample of tap `k` of kernel `idx`
    /// (a probe measurement: convolution with a one-hot patch).
    pub fn sample_weight(&mut self, idx: usize, k: usize) -> f64 {
        let tap = &self.bank[idx].taps[k];
        let (pp, pm, dof, ge) = (tap.real_p_plus, tap.real_p_minus, tap.real_dof, tap.gain_eff);
        let plus = if pp > 0.0 {
            self.src.intensity_dof(k, pp, dof)
        } else {
            0.0
        };
        let minus = if pm > 0.0 {
            self.src.intensity_dof(k, pm, dof)
        } else {
            0.0
        };
        ge * (plus - minus)
    }

    /// Convolve a stream of im2col patches (each `num_taps` activations)
    /// with kernel `idx`.  Each patch occupies `num_taps` optical symbols;
    /// the weight fluctuates per symbol (fresh chaos every 37.5 ps).
    ///
    /// `patches.len()` must be a multiple of `num_taps`; writes one output
    /// per patch into `out`.
    pub fn conv_patches(&mut self, idx: usize, patches: &[f32], out: &mut [f32]) {
        let nt = self.num_taps();
        assert_eq!(patches.len() % nt, 0);
        let n = patches.len() / nt;
        assert!(out.len() >= n);
        conv_patches_core(
            &self.bank[idx].flat,
            patches,
            nt,
            self.cfg.scale_dac,
            &self.eom,
            &mut self.src,
            &mut self.detector,
            &mut self.scratch,
            out,
        );
        self.stats.convolutions += n as u64;
        self.stats.clock.advance_symbols((n * nt) as u64);
    }

    /// Full probabilistic depthwise 3x3 convolution over a (C, H, W) map:
    /// channel `c` uses kernel `bank_base + c`.  SAME padding; im2col
    /// streaming per channel.  Returns a (C, H, W) row-major buffer.
    pub fn depthwise_conv(
        &mut self,
        bank_base: usize,
        x: &[f32],
        c: usize,
        h: usize,
        w: usize,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; c * h * w];
        self.depthwise_conv_into(bank_base, x, c, h, w, &mut out);
        out
    }

    /// Allocation-free [`Self::depthwise_conv`]: writes into a caller-owned
    /// buffer and reuses the machine's im2col scratch.  This is the serving
    /// hot path; RNG consumption order is identical to `depthwise_conv`.
    pub fn depthwise_conv_into(
        &mut self,
        bank_base: usize,
        x: &[f32],
        c: usize,
        h: usize,
        w: usize,
        out: &mut [f32],
    ) {
        assert_eq!(x.len(), c * h * w);
        assert!(out.len() >= c * h * w);
        let nt = self.num_taps();
        assert_eq!(nt, 9, "depthwise path assumes 3x3 kernels");
        // take the scratch plane out so conv_patches can borrow &mut self
        let mut patches = std::mem::take(&mut self.scratch.patches);
        if patches.len() < h * w * nt {
            patches.resize(h * w * nt, 0.0);
        }
        for ch in 0..c {
            let plane = &x[ch * h * w..(ch + 1) * h * w];
            im2col_3x3(plane, h, w, &mut patches);
            let out_plane = &mut out[ch * h * w..(ch + 1) * h * w];
            // slice to this call's plane: the grow-only scratch may be
            // longer than h*w*9 after a larger earlier request
            self.conv_patches(bank_base + ch, &patches[..h * w * nt], out_plane);
        }
        self.scratch.patches = patches;
    }

    /// The detector's ADC quantizer (exposed for parity tests with L2).
    pub fn adc(&self) -> Quantizer {
        Quantizer::new(self.cfg.scale_adc)
    }

    /// Simulated-optical-time throughput report.
    pub fn throughput_report(&self) -> String {
        let h = timing::headline();
        format!(
            "convolutions={} optical_time={:.1} ns wall-equivalent optical rate={:.2} Gconv/s",
            self.stats.convolutions,
            self.stats.clock.elapsed_ns(),
            h.convolutions_per_sec / 1e9
        )
    }
}

/// Symbols at the modulator's extinction floor carry <= 1e-3 of a tap's
/// weight; skipping their Gamma draws changes the output by less than the
/// receiver noise floor and saves ~40 % of sampling on post-ReLU
/// activations (see EXPERIMENTS.md §Perf).
pub(crate) const T_FLOOR: f64 = 1.5e-3;

/// Fill `trans` with channel `k`'s EOM transmissions for every patch and
/// return how many lie above the extinction floor — the number of symbols
/// that will consume entropy draws.  Shared by the inline and the banked
/// conv cores: both must count `m` identically or the off-vs-banked
/// statistical equivalence (and the bank's stream advance) silently breaks.
fn live_transmissions(eom: &Eom, patches: &[f32], nt: usize, k: usize, trans: &mut [f32]) -> usize {
    let mut m = 0usize;
    for (p, t) in trans.iter_mut().enumerate() {
        *t = eom.transmission(patches[p * nt + k]);
        if (*t as f64) > T_FLOOR {
            m += 1;
        }
    }
    m
}

/// The photonic conv inner loop, callable with any entropy streams — the
/// machine's own, or an independently seeded worker shard's (parallel
/// `sample_conv`).  Channel-outer with bulk per-channel Gamma draws: each
/// spectral channel owns an independent stream and two-rail taps use the
/// paired fill (plus-then-minus per symbol), so per-channel stream
/// consumption order — and therefore every output bit — matches the
/// historical pixel-outer scalar loop.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_patches_core(
    flat: &[FlatTap],
    patches: &[f32],
    nt: usize,
    scale_dac: f32,
    eom: &Eom,
    src: &mut ChaoticLightSource,
    detector: &mut Detector,
    scratch: &mut ScratchArena,
    out: &mut [f32],
) {
    let n = patches.len() / nt;
    let acc = grow(&mut scratch.acc, n);
    acc.fill(0.0);
    let trans = grow(&mut scratch.trans, n);
    let plus = grow(&mut scratch.rail_plus, n);
    let minus = grow(&mut scratch.rail_minus, n);
    for (k, tap) in flat.iter().enumerate().take(nt) {
        // transmissions for this channel; only symbols above the extinction
        // floor consume Gamma draws
        let m = live_transmissions(eom, patches, nt, k, trans);
        if m == 0 {
            continue;
        }
        match (tap.p_plus > 0.0, tap.p_minus > 0.0) {
            (true, true) => {
                // both rails lit: draw plus-then-minus per symbol, the
                // scalar loop's exact stream order
                src.fill_intensity_pair_dof(
                    k,
                    tap.p_plus,
                    tap.p_minus,
                    tap.dof,
                    &mut plus[..m],
                    &mut minus[..m],
                );
            }
            (true, false) => {
                src.fill_intensity_dof(k, tap.p_plus, tap.dof, &mut plus[..m]);
                minus[..m].fill(0.0);
            }
            (false, true) => {
                plus[..m].fill(0.0);
                src.fill_intensity_dof(k, tap.p_minus, tap.dof, &mut minus[..m]);
            }
            (false, false) => {
                plus[..m].fill(0.0);
                minus[..m].fill(0.0);
            }
        }
        let mut j = 0usize;
        for (p, a) in acc.iter_mut().enumerate() {
            let t = trans[p] as f64;
            if t <= T_FLOOR {
                continue;
            }
            *a += tap.gain_eff * (plus[j] - minus[j]) * t;
            j += 1;
        }
    }
    for (p, o) in out.iter_mut().take(n).enumerate() {
        *o = detector.read((acc[p] * scale_dac as f64) as f32);
    }
}

/// Bank-aware variant of [`conv_patches_core`] for the decoupled entropy
/// pipeline: instead of drawing rail intensities inline, each tap's
/// realized weights arrive from `fill(k, out)` — a per-(kernel, tap)
/// [`crate::entropy::pipeline::EntropyStream`] that is either prefetched by
/// a background producer or drawn synchronously from the same stream.  With
/// the weights pre-realized, the inner loop is a pure FMA over the
/// prefetched plane.  The extinction-floor skip is preserved: only symbols
/// above [`T_FLOOR`] consume weight draws, so the bank's streams advance
/// exactly as far as the inline path's would.
pub(crate) fn conv_patches_banked<F: FnMut(usize, &mut [f64])>(
    patches: &[f32],
    nt: usize,
    scale_dac: f32,
    eom: &Eom,
    mut fill: F,
    detector: &mut Detector,
    scratch: &mut ScratchArena,
    out: &mut [f32],
) {
    let n = patches.len() / nt;
    let acc = grow(&mut scratch.acc, n);
    acc.fill(0.0);
    let trans = grow(&mut scratch.trans, n);
    let weights = grow(&mut scratch.rail_plus, n);
    for k in 0..nt {
        let m = live_transmissions(eom, patches, nt, k, trans);
        if m == 0 {
            continue;
        }
        fill(k, &mut weights[..m]);
        let mut j = 0usize;
        for (p, a) in acc.iter_mut().enumerate() {
            let t = trans[p] as f64;
            if t <= T_FLOOR {
                continue;
            }
            *a += weights[j] * t;
            j += 1;
        }
    }
    for (p, o) in out.iter_mut().take(n).enumerate() {
        *o = detector.read((acc[p] * scale_dac as f64) as f32);
    }
}

/// im2col for SAME-padded 3x3 windows: patches[(i*w + j)*9 + k] =
/// x[i+dy-1, j+dx-1] with (dy, dx) = divmod(k, 3), zero outside.
pub fn im2col_3x3(x: &[f32], h: usize, w: usize, patches: &mut [f32]) {
    assert_eq!(x.len(), h * w);
    assert!(patches.len() >= h * w * 9);
    for i in 0..h {
        for j in 0..w {
            let base = (i * w + j) * 9;
            for k in 0..9 {
                let (dy, dx) = (k / 3, k % 3);
                let y = i as isize + dy as isize - 1;
                let xx = j as isize + dx as isize - 1;
                patches[base + k] = if y >= 0 && y < h as isize && xx >= 0 && xx < w as isize {
                    x[y as usize * w + xx as usize]
                } else {
                    0.0
                };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::mathstat::{mean_f32, std_f32, Welford};

    fn quiet_machine(seed: u64) -> PhotonicMachine {
        PhotonicMachine::new(MachineConfig {
            rx_noise: 0.0,
            actuator_sigma: 0.0,
            actuator_jitter: 0.0,
            ripple_rms_ps: 0.0,
            seed,
            ..MachineConfig::default()
        })
    }

    fn targets9(mu: f32, sigma: f32) -> Vec<TapTarget> {
        vec![TapTarget { mu, sigma }; 9]
    }

    #[test]
    fn solve_program_recovers_moments_in_range() {
        let m = quiet_machine(1);
        // sigma/|mu| within [1/sqrt(M_max), 1/sqrt(M_min)] -> exactly realizable
        let tgt = TapTarget { mu: 0.8, sigma: 0.5 };
        let p = m.solve_program(0, tgt);
        assert!((p.realized_mu() - 0.8).abs() < 1e-6);
        assert!((p.realized_sigma() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn solve_program_negative_mu_uses_minus_rail() {
        let m = quiet_machine(1);
        let p = m.solve_program(0, TapTarget { mu: -0.6, sigma: 0.4 });
        assert!(p.cmd_p_minus > p.cmd_p_plus);
        assert!((p.realized_mu() + 0.6).abs() < 1e-6);
    }

    #[test]
    fn sigma_floor_is_enforced() {
        let m = quiet_machine(1);
        // ask for far less noise than the hardware can do
        let p = m.solve_program(0, TapTarget { mu: 1.0, sigma: 0.01 });
        let floor = 1.0 / m.cfg.source.dof(m.cfg.source.bw_max_ghz).sqrt();
        assert!((p.realized_sigma() - floor).abs() < 1e-6);
    }

    #[test]
    fn common_mode_boosts_sigma_beyond_single_rail() {
        let m = quiet_machine(1);
        // sigma larger than |mu| / sqrt(M_min): needs common-mode power
        let p = m.solve_program(0, TapTarget { mu: 0.1, sigma: 0.5 });
        assert!(p.cmd_p_minus > 0.0, "needs minus-rail common mode");
        assert!((p.realized_mu() - 0.1).abs() < 1e-6);
        assert!((p.realized_sigma() - 0.5).abs() < 1e-6);
    }

    #[test]
    fn zero_mu_pure_noise_tap() {
        let m = quiet_machine(1);
        let p = m.solve_program(0, TapTarget { mu: 0.0, sigma: 0.3 });
        assert!((p.realized_mu()).abs() < 1e-6);
        assert!((p.realized_sigma() - 0.3).abs() < 1e-6);
    }

    #[test]
    fn sampled_weights_match_program_moments() {
        let mut m = quiet_machine(2);
        let idx = m.load_kernel(&targets9(0.7, 0.45));
        let mut w = Welford::new();
        for _ in 0..40_000 {
            w.push(m.sample_weight(idx, 3));
        }
        assert!((w.mean() - 0.7).abs() < 0.02, "mean {}", w.mean());
        assert!((w.std() - 0.45).abs() < 0.02, "std {}", w.std());
    }

    #[test]
    fn conv_patch_computes_weighted_sum() {
        let mut m = quiet_machine(3);
        // near-deterministic taps (sigma at the floor)
        let idx = m.load_kernel(&targets9(0.5, 0.0));
        let patch: Vec<f32> = (0..9).map(|i| 0.25 * (i % 4) as f32).collect();
        let mut outs = Vec::new();
        let mut out = [0.0f32];
        for _ in 0..3000 {
            m.conv_patches(idx, &patch, &mut out);
            outs.push(out[0]);
        }
        let want: f32 = patch.iter().map(|&x| 0.5 * x).sum();
        let got = mean_f32(&outs) as f32;
        // sigma floor (~0.19 per tap) leaves noise on each draw; the mean
        // converges to the deterministic weighted sum
        assert!((got - want).abs() < 0.05, "got {got} want {want}");
        assert!(std_f32(&outs) > 0.0);
    }

    #[test]
    fn output_variance_scales_with_target_sigma() {
        let mut m = quiet_machine(4);
        let lo = m.load_kernel(&targets9(0.4, 0.2));
        let hi = m.load_kernel(&targets9(0.4, 0.6));
        let patch = [1.0f32; 9];
        let mut out = [0.0f32];
        let run = |m: &mut PhotonicMachine, idx: usize, out: &mut [f32; 1]| {
            let mut v = Vec::with_capacity(2000);
            for _ in 0..2000 {
                m.conv_patches(idx, &patch, out);
                v.push(out[0]);
            }
            std_f32(&v)
        };
        let s_lo = run(&mut m, lo, &mut out);
        let s_hi = run(&mut m, hi, &mut out);
        assert!(s_hi > 2.0 * s_lo, "lo {s_lo} hi {s_hi}");
    }

    #[test]
    fn im2col_matches_manual_window() {
        let h = 3;
        let w = 4;
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut p = vec![0.0f32; h * w * 9];
        im2col_3x3(&x, h, w, &mut p);
        // center pixel (1,1): window rows [0..3) x [0..3)
        let base = (w + 1) * 9;
        let want = [0.0, 1.0, 2.0, 4.0, 5.0, 6.0, 8.0, 9.0, 10.0];
        assert_eq!(&p[base..base + 9], &want);
        // corner (0,0): top-left padding
        let want0 = [0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 4.0, 5.0];
        assert_eq!(&p[..9], &want0);
    }

    #[test]
    fn depthwise_conv_mean_matches_reference() {
        let mut m = quiet_machine(5);
        let (c, h, w) = (2usize, 5usize, 5usize);
        let taps = [
            targets9(0.3, 0.0),
            targets9(-0.2, 0.0),
        ];
        for t in &taps {
            m.load_kernel(t);
        }
        let x: Vec<f32> = (0..c * h * w).map(|i| ((i % 7) as f32) * 0.3).collect();
        // average many stochastic runs -> converges to deterministic conv
        let reps = 600;
        let mut acc = vec![0.0f64; c * h * w];
        for _ in 0..reps {
            let y = m.depthwise_conv(0, &x, c, h, w);
            for (a, v) in acc.iter_mut().zip(y) {
                *a += v as f64;
            }
        }
        let mut patches = vec![0.0f32; h * w * 9];
        for ch in 0..c {
            im2col_3x3(&x[ch * h * w..(ch + 1) * h * w], h, w, &mut patches);
            let wk = if ch == 0 { 0.3f32 } else { -0.2 };
            for p in 0..h * w {
                let want: f32 = patches[p * 9..(p + 1) * 9].iter().map(|&v| wk * v).sum();
                let got = (acc[ch * h * w + p] / reps as f64) as f32;
                assert!(
                    (got - want).abs() < 0.12,
                    "ch {ch} p {p}: got {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn flat_cache_tracks_realized_taps() {
        let mut m = quiet_machine(12);
        let idx = m.load_kernel(&targets9(0.5, 0.3));
        let kp = m.kernel(idx);
        assert_eq!(kp.flat().len(), 9);
        for (tap, flat) in kp.taps.iter().zip(kp.flat()) {
            assert_eq!(flat.gain_eff, tap.gain_eff);
            let mu_err = (flat.p_plus - flat.p_minus) * flat.gain_eff - tap.realized_mu();
            assert!(mu_err.abs() < 1e-12);
        }
        // reprogramming rebuilds the cache
        let cmds: Vec<(f64, f64, f64)> = kp
            .taps
            .iter()
            .map(|t| (t.cmd_p_plus * 0.5, t.cmd_p_minus, t.cmd_dof))
            .collect();
        let before = kp.flat()[0];
        m.reprogram_kernel(idx, cmds);
        let after = m.kernel(idx).flat()[0];
        assert!(after.p_plus < before.p_plus, "{after:?} vs {before:?}");
    }

    #[test]
    fn depthwise_conv_handles_shrinking_dims_after_scratch_growth() {
        // the grow-only im2col scratch must not leak a larger previous
        // request's length into a smaller one
        let mut m = quiet_machine(17);
        m.load_kernel(&targets9(0.3, 0.2));
        let big: Vec<f32> = (0..36).map(|i| 0.1 * (i % 5) as f32).collect();
        let _ = m.depthwise_conv(0, &big, 1, 6, 6);
        let small: Vec<f32> = (0..9).map(|i| 0.1 * (i % 5) as f32).collect();
        let y = m.depthwise_conv(0, &small, 1, 3, 3); // must not panic
        assert_eq!(y.len(), 9);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn depthwise_conv_into_matches_allocating_variant() {
        let (c, h, w) = (2usize, 4usize, 4usize);
        let targets = targets9(0.3, 0.2);
        let x: Vec<f32> = (0..c * h * w).map(|i| 0.2 * (i % 5) as f32).collect();

        let mut a = quiet_machine(21);
        let mut b = quiet_machine(21);
        for _ in 0..c {
            a.load_kernel(&targets);
            b.load_kernel(&targets);
        }
        for _ in 0..3 {
            let ya = a.depthwise_conv(0, &x, c, h, w);
            let mut yb = vec![0.0f32; c * h * w];
            b.depthwise_conv_into(0, &x, c, h, w, &mut yb);
            assert_eq!(ya, yb, "identical machines, identical streams");
        }
    }

    #[test]
    fn stats_track_optical_time() {
        let mut m = quiet_machine(6);
        let idx = m.load_kernel(&targets9(0.1, 0.1));
        let patches = vec![0.5f32; 9 * 100];
        let mut out = vec![0.0f32; 100];
        m.conv_patches(idx, &patches, &mut out);
        assert_eq!(m.stats.convolutions, 100);
        assert_eq!(m.stats.clock.symbols(), 900);
        assert!((m.stats.clock.elapsed_ns() - 900.0 * 0.0375).abs() < 1e-6);
    }

    #[test]
    fn actuator_error_perturbs_realization() {
        let mut m = PhotonicMachine::new(MachineConfig {
            actuator_sigma: 0.05,
            actuator_jitter: 0.01,
            rx_noise: 0.0,
            seed: 8,
            ..MachineConfig::default()
        });
        let idx = m.load_kernel(&targets9(0.8, 0.4));
        let kp = m.kernel(idx);
        let off: f64 = kp
            .taps
            .iter()
            .map(|t| (t.realized_mu() - 0.8).abs())
            .sum::<f64>()
            / 9.0;
        assert!(off > 1e-4, "actuator error should shift realization");
        assert!(off < 0.2, "but not wildly: {off}");
    }
}
