//! Broadband electro-optic modulator (EOM) input path.
//!
//! The EOM imprints the (DAC-quantized) input vector simultaneously onto all
//! spectral channels as a transmission factor `t ∈ [0, 1]`.  Activations
//! reaching the photonic stage are non-negative (post-ReLU) and already on
//! the 8-bit DAC grid (`fwd_pre` ends in `fake_quant8`), so the modulator
//! maps `x / full_scale` onto its linear transmission range.  A small static
//! extinction floor models finite modulator extinction ratio.

use super::converters::Quantizer;

#[derive(Debug, Clone)]
pub struct Eom {
    dac: Quantizer,
    /// Transmission floor from finite extinction ratio (e.g. 30 dB -> 1e-3).
    extinction_floor: f32,
}

impl Eom {
    pub fn new(full_scale: f32, extinction_db: f32) -> Self {
        Self {
            dac: Quantizer::new(full_scale),
            extinction_floor: 10f32.powf(-extinction_db / 10.0),
        }
    }

    /// Encode one activation into a channel transmission factor in [floor, 1].
    #[inline]
    pub fn transmission(&self, x: f32) -> f32 {
        let xq = self.dac.quantize(x.max(0.0));
        let t = xq / self.dac.scale;
        t.clamp(self.extinction_floor, 1.0)
    }

    /// Encode a full input stream (time-major) into transmissions.
    pub fn encode_stream(&self, xs: &[f32], out: &mut [f32]) {
        assert_eq!(xs.len(), out.len());
        for (o, &x) in out.iter_mut().zip(xs) {
            *o = self.transmission(x);
        }
    }

    pub fn full_scale(&self) -> f32 {
        self.dac.scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transmission_is_normalized_and_clipped() {
        let eom = Eom::new(4.0, 30.0);
        assert!((eom.transmission(4.0) - 1.0).abs() < 1e-6);
        assert!((eom.transmission(2.0) - 0.5).abs() < 0.01);
        // negative inputs are floored (activations are non-negative by design)
        assert!(eom.transmission(-3.0) <= 1e-3 + 1e-9);
        // overdrive clips at full transmission
        assert!((eom.transmission(40.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn extinction_floor_applied() {
        let eom = Eom::new(4.0, 30.0);
        let t = eom.transmission(0.0);
        assert!((t - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn stream_encoding_matches_scalar() {
        let eom = Eom::new(8.0, 25.0);
        let xs = [0.0, 1.0, 7.5, 8.0];
        let mut out = [0.0f32; 4];
        eom.encode_stream(&xs, &mut out);
        for (i, &x) in xs.iter().enumerate() {
            assert_eq!(out[i], eom.transmission(x));
        }
    }

    #[test]
    fn quantization_grid_visible() {
        let eom = Eom::new(4.0, 30.0);
        // two inputs inside the same LSB bucket map to the same transmission
        assert_eq!(eom.transmission(1.000), eom.transmission(1.010));
    }
}
