//! Uncertainty evaluation over dataset splits (Fig. 4 and Fig. 5).
//!
//! Runs the engine over an in-domain test split plus aleatoric/epistemic
//! probe splits, collecting per-input MI and SE scores, then derives the
//! paper's reported quantities: OOD ROC/AUROC (Fig. 4(c) / Fig. 5(f)),
//! accuracy with and without MI rejection at the optimal threshold
//! (Fig. 4(d) / Fig. 5(f)), the confusion matrix with rejection column
//! (Fig. 4(d)), and the MI–SE scatter clusters (Fig. 5(e)).

use anyhow::Result;

use crate::bnn::confusion::ConfusionMatrix;
use crate::bnn::rocauc::{auroc, best_threshold, roc_curve, RocPoint};
use crate::coordinator::Engine;
use crate::data::Dataset;
use crate::sampler::RequestBudget;

/// Per-split uncertainty scores.
#[derive(Debug, Clone)]
pub struct SplitScores {
    pub name: String,
    pub mi: Vec<f64>,
    pub se: Vec<f64>,
    pub predicted: Vec<usize>,
    pub labels: Vec<i64>,
    /// Stochastic passes spent per input (constant on the fixed rule,
    /// input-dependent under adaptive stopping).
    pub samples: Vec<usize>,
}

impl SplitScores {
    pub fn accuracy(&self) -> f64 {
        if self.labels.is_empty() {
            return 0.0;
        }
        let c = self
            .predicted
            .iter()
            .zip(&self.labels)
            .filter(|&(&p, &l)| p as i64 == l)
            .count();
        c as f64 / self.labels.len() as f64
    }

    /// Mean stochastic passes per input — the adaptive sampler's economy.
    pub fn mean_samples(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<usize>() as f64 / self.samples.len() as f64
    }
}

/// Classify up to `limit` inputs of a split through the engine.
pub fn eval_split(engine: &mut Engine, ds: &Dataset, limit: usize) -> Result<SplitScores> {
    eval_split_budget(engine, ds, limit, &RequestBudget::default())
}

/// [`eval_split`] with per-request budget overrides (the accuracy-vs-cost
/// sweeps drive this with a range of `target_confidence` values).
pub fn eval_split_budget(
    engine: &mut Engine,
    ds: &Dataset,
    limit: usize,
    budget: &RequestBudget,
) -> Result<SplitScores> {
    let n = ds.n.min(limit);
    let bsize = 8usize;
    let mut mi = Vec::with_capacity(n);
    let mut se = Vec::with_capacity(n);
    let mut predicted = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let mut samples = Vec::with_capacity(n);
    let mut buf = Vec::new();
    let mut i = 0;
    while i < n {
        let b = bsize.min(n - i);
        buf.clear();
        for j in i..i + b {
            buf.extend_from_slice(ds.image(j));
            labels.push(ds.labels[j]);
        }
        for r in engine.classify_with_budget(&buf, b, budget)? {
            mi.push(r.predictive.mutual_information);
            se.push(r.predictive.softmax_entropy);
            predicted.push(r.predictive.predicted);
            samples.push(r.samples_used);
        }
        i += b;
    }
    Ok(SplitScores {
        name: ds.name.clone(),
        mi,
        se,
        predicted,
        labels,
        samples,
    })
}

/// One point of the accuracy-vs-sampling-cost trade-off curve.
#[derive(Debug, Clone, Copy)]
pub struct AdaptivePoint {
    pub target_confidence: f64,
    pub mean_samples: f64,
    pub accuracy: f64,
}

/// Sweep `target_confidence` values over a split: each point classifies
/// the split under that per-request confidence target and reports the
/// resulting mean samples/request next to the accuracy — the paper's
/// sampling-cost claim as a measurable curve.
pub fn accuracy_vs_samples(
    engine: &mut Engine,
    ds: &Dataset,
    limit: usize,
    targets: &[f64],
) -> Result<Vec<AdaptivePoint>> {
    let mut curve = Vec::with_capacity(targets.len());
    for &t in targets {
        let budget = RequestBudget {
            max_samples: None,
            target_confidence: Some(t),
        };
        let scores = eval_split_budget(engine, ds, limit, &budget)?;
        curve.push(AdaptivePoint {
            target_confidence: t,
            mean_samples: scores.mean_samples(),
            accuracy: scores.accuracy(),
        });
    }
    Ok(curve)
}

/// Everything the Fig. 4 / Fig. 5 panels report.
#[derive(Debug, Clone)]
pub struct UncertaintyReport {
    /// In-domain scores (test split).
    pub id: SplitScores,
    /// Epistemic probe scores (erythroblasts / fashion).
    pub epistemic: SplitScores,
    /// Aleatoric probe scores (ambiguous digits), when applicable.
    pub aleatoric: Option<SplitScores>,
    /// OOD detector: MI score, epistemic-vs-ID. (Fig. 4(c), Fig. 5(f))
    pub ood_auroc: f64,
    pub ood_roc: Vec<RocPoint>,
    pub ood_best: RocPoint,
    /// Aleatoric detector: SE score, ambiguous-vs-ID. (Fig. 5(f))
    pub aleatoric_auroc: Option<f64>,
    /// Plain ID accuracy (no rejection).
    pub acc_plain: f64,
    /// ID accuracy over accepted inputs at the optimal MI threshold.
    pub acc_reject: f64,
    /// The MI threshold used for rejection.
    pub mi_threshold: f64,
    /// Confusion matrix with rejection at that threshold (OOD rows included).
    pub confusion: ConfusionMatrix,
}

/// Build the full report from collected split scores.
pub fn build_report(
    id: SplitScores,
    epistemic: SplitScores,
    aleatoric: Option<SplitScores>,
    n_classes: usize,
) -> UncertaintyReport {
    let ood_roc = roc_curve(&epistemic.mi, &id.mi);
    let ood_auroc = auroc(&epistemic.mi, &id.mi);
    let ood_best = best_threshold(&epistemic.mi, &id.mi);
    let thr = ood_best.threshold;

    let acc_plain = id.accuracy();
    let mut confusion = ConfusionMatrix::new(n_classes);
    for i in 0..id.labels.len() {
        let pred = if id.mi[i] >= thr {
            n_classes // rejected
        } else {
            id.predicted[i]
        };
        confusion.record(id.labels[i] as usize, pred);
    }
    for i in 0..epistemic.labels.len() {
        let pred = if epistemic.mi[i] >= thr {
            n_classes
        } else {
            epistemic.predicted[i]
        };
        confusion.record(n_classes, pred);
    }
    let acc_reject = confusion.accepted_accuracy();
    let aleatoric_auroc = aleatoric.as_ref().map(|a| auroc(&a.se, &id.se));
    UncertaintyReport {
        id,
        epistemic,
        aleatoric,
        ood_auroc,
        ood_roc,
        ood_best,
        aleatoric_auroc,
        acc_plain,
        acc_reject,
        mi_threshold: thr,
        confusion,
    }
}

impl UncertaintyReport {
    /// Summary lines in the paper's terms.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "OOD detector (MI):      AUROC = {:.2}%   [paper Fig4c: 91.16% blood / Fig5f: 84.42% mnist]\n",
            self.ood_auroc * 100.0
        ));
        if let Some(a) = self.aleatoric_auroc {
            s.push_str(&format!(
                "aleatoric detector (SE): AUROC = {:.2}%   [paper Fig5f: 88.03%]\n",
                a * 100.0
            ));
        }
        s.push_str(&format!(
            "ID accuracy:            {:.2}% -> {:.2}% with MI rejection @ {:.5}\n",
            self.acc_plain * 100.0,
            self.acc_reject * 100.0,
            self.mi_threshold
        ));
        s.push_str(&format!(
            "OOD rejection rate:     {:.2}%  (ID falsely rejected: {:.2}%)\n",
            self.confusion.ood_rejection_rate() * 100.0,
            self.confusion.id_rejection_rate() * 100.0
        ));
        s
    }

    /// The Fig. 5(e) scatter: (mi, se, cluster-id) rows.
    pub fn scatter_rows(&self) -> Vec<(f64, f64, u8)> {
        let mut rows = Vec::new();
        for i in 0..self.id.mi.len() {
            rows.push((self.id.mi[i], self.id.se[i], 0u8));
        }
        if let Some(a) = &self.aleatoric {
            for i in 0..a.mi.len() {
                rows.push((a.mi[i], a.se[i], 1u8));
            }
        }
        for i in 0..self.epistemic.mi.len() {
            rows.push((self.epistemic.mi[i], self.epistemic.se[i], 2u8));
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scores(
        name: &str,
        mi: Vec<f64>,
        se: Vec<f64>,
        pred: Vec<usize>,
        lab: Vec<i64>,
    ) -> SplitScores {
        let samples = vec![10usize; lab.len()];
        SplitScores {
            name: name.into(),
            mi,
            se,
            predicted: pred,
            labels: lab,
            samples,
        }
    }

    #[test]
    fn mean_samples_over_split() {
        let mut s = scores("id", vec![0.0; 3], vec![0.0; 3], vec![0; 3], vec![0; 3]);
        s.samples = vec![2, 4, 9];
        assert!((s.mean_samples() - 5.0).abs() < 1e-12);
        s.samples.clear();
        assert_eq!(s.mean_samples(), 0.0);
    }

    #[test]
    fn report_with_clean_separation() {
        // ID: low MI, mostly correct; OOD: high MI
        let id = scores(
            "id",
            vec![0.01, 0.02, 0.015, 0.45],
            vec![0.1; 4],
            vec![0, 1, 2, 0],
            vec![0, 1, 2, 1], // last one wrong AND uncertain
        );
        let ood = scores("ood", vec![0.5, 0.6, 0.41], vec![0.2; 3], vec![0, 1, 2], vec![9, 9, 9]);
        let rep = build_report(id, ood, None, 3);
        assert!(rep.ood_auroc > 0.9);
        assert!((rep.acc_plain - 0.75).abs() < 1e-9);
        // the wrong-but-uncertain ID sample is rejected -> accuracy improves
        assert!(rep.acc_reject > rep.acc_plain);
        assert!(rep.confusion.ood_rejection_rate() > 0.99);
    }

    #[test]
    fn aleatoric_auroc_uses_se() {
        let id = scores("id", vec![0.0; 4], vec![0.1, 0.2, 0.15, 0.12], vec![0; 4], vec![0; 4]);
        let ood = scores("ood", vec![0.5; 2], vec![0.2; 2], vec![0; 2], vec![9; 2]);
        let amb = scores("amb", vec![0.0; 3], vec![0.9, 1.0, 0.8], vec![0; 3], vec![0; 3]);
        let rep = build_report(id, ood, Some(amb), 3);
        assert!((rep.aleatoric_auroc.unwrap() - 1.0).abs() < 1e-9);
        let rows = rep.scatter_rows();
        assert_eq!(rows.len(), 4 + 3 + 2);
        assert!(rows.iter().any(|r| r.2 == 1));
    }
}
