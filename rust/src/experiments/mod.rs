//! Paper-experiment drivers: the code that regenerates every figure/table.
//!
//! Each function here corresponds to a row of DESIGN.md's experiment index
//! and is callable from `pbm report ...`, the bench binaries, and the
//! examples — one implementation, three surfaces.

pub mod uncertainty;

pub use uncertainty::{eval_split, SplitScores, UncertaintyReport};
