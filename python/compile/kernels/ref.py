"""Pure-jnp reference oracle for the L1 Pallas kernels.

These are the ground-truth implementations the Pallas kernels are tested
against (pytest + hypothesis sweeps in ``python/tests``).  They model the
photonic Bayesian machine's probabilistic depthwise convolution:

    y[b, c, i, j] = sum_k  (mu[c, k] + sigma[c, k] * eps[b, c, i, j, k])
                           * x_pad[b, c, i + dy(k), j + dx(k)]

where ``k`` indexes the machine's nine spectral weight channels (== the nine
taps of a 3x3 depthwise kernel), ``mu``/``sigma`` are the programmed optical
power / bandwidth of each channel, and ``eps`` is the chaotic-light noise
drawn per 37.5 ps convolution window (i.e. per output element), supplied
externally because the entropy is physical, not pseudo-random.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Spatial kernel edge of the photonic machine: nine spectral channels map to
#: the nine taps of one 3x3 depthwise kernel (paper, Fig. 2(a)).
KERNEL_EDGE = 3
NUM_TAPS = KERNEL_EDGE * KERNEL_EDGE


def prob_depthwise_conv3x3_ref(x, mu, sigma, eps):
    """Probabilistic 3x3 depthwise ("fully grouped") convolution, SAME pad.

    Args:
      x:     (B, C, H, W) activations (the EOM-encoded input stream).
      mu:    (C, 9) per-channel tap means (programmed channel power).
      sigma: (C, 9) per-channel tap standard deviations (channel bandwidth).
      eps:   (B, C, H, W, 9) unit-variance noise per output element and tap.

    Returns:
      (B, C, H, W) convolution with weights sampled per output element.
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    out = jnp.zeros_like(x)
    for k in range(NUM_TAPS):
        dy, dx = divmod(k, KERNEL_EDGE)
        win = xp[:, :, dy : dy + h, dx : dx + w]
        wk = mu[None, :, None, None, k] + sigma[None, :, None, None, k] * eps[..., k]
        out = out + wk * win
    return out


def depthwise_conv3x3_ref(x, taps):
    """Deterministic 3x3 depthwise convolution, SAME pad.

    Args:
      x:    (B, C, H, W)
      taps: (C, 9)

    Returns: (B, C, H, W)
    """
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    out = jnp.zeros_like(x)
    for k in range(NUM_TAPS):
        dy, dx = divmod(k, KERNEL_EDGE)
        win = xp[:, :, dy : dy + h, dx : dx + w]
        out = out + taps[None, :, None, None, k] * win
    return out


def fake_quant8_ref(x, scale):
    """8-bit symmetric fake quantization (DAC/ADC model), no STE.

    ``q = clip(round(x / scale * 127), -128, 127) * scale / 127``
    """
    q = jnp.clip(jnp.round(x / scale * 127.0), -128.0, 127.0)
    return q * scale / 127.0
