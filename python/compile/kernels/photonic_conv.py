"""L1 Pallas kernels for the photonic Bayesian machine.

The machine's compute hot-spot is a nine-tap *probabilistic* convolution:
nine spectral channels of a chaotic ASE source each carry one stochastic
weight (mean = channel power, std = channel bandwidth), an EOM time-encodes
the activation stream onto all channels, and a chirped grating shifts channel
``k`` by ``k`` symbols so a single photodetector integrates

    y[t] = sum_k (mu_k + sigma_k * eps_k(t)) * x[t - k].

Hardware adaptation (GPU/photonics -> TPU, see DESIGN.md §Hardware-Adaptation):

* the nine spectral channels become a **tap axis resident in VMEM** — taps
  are O(C*9) floats, trivially resident; the activation map is the streamed
  operand, blocked one (H, W) map per grid step via ``BlockSpec``;
* the chirped grating's one-symbol-per-channel delay becomes the **static
  shift structure** of an unrolled nine-term accumulation (no gathers, no
  runtime indexing — the shifts are compile-time slices);
* chaotic-light randomness enters as an **external noise operand** ``eps``
  (physical entropy is data, keeping the kernel deterministic and therefore
  AOT-exportable as plain HLO);
* the DAC/ADC pair becomes an 8-bit fake-quantization kernel with a
  straight-through estimator so SVI gradients pass through unchanged.

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute, and this repo's runtime is
the CPU client.  Pallas has no general reverse-mode AD, so each kernel is
wrapped in ``jax.custom_vjp`` with an analytic backward pass in pure jnp
(the ops are linear / piecewise-linear, so the VJPs are exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import KERNEL_EDGE, NUM_TAPS

# Always interpret: the CPU PJRT plugin cannot run Mosaic custom-calls.
_INTERPRET = True


# ---------------------------------------------------------------------------
# Probabilistic depthwise 3x3 convolution (the photonic machine itself)
# ---------------------------------------------------------------------------


def _prob_dws_kernel(x_ref, mu_ref, sig_ref, eps_ref, o_ref, *, h: int, w: int):
    """Single-block 9-tap probabilistic conv over the full (B, C, H, W) map.

    The kernel is one VMEM-resident block (no grid): for the paper's
    probabilistic stage (B<=100, C=64, 7x7 maps) the operands total
    x (B,C,9,9) + eps (B,C,7,7,9) + out (B,C,7,7) ≈ 10 MiB f32 at B=100,
    inside the ~16 MiB VMEM budget.  The unrolled static shifts are the
    chirped grating's per-channel symbol delays; there are no gathers and
    no serialized grid loop (a (B, C) grid lowers to B*C sequential
    while-loop steps under interpret mode — measured 12 s/train-step vs
    ~0.1 s for this single-block form; see EXPERIMENTS.md §Perf).
    For larger maps, block over the batch axis before the taps.
    """
    xw = x_ref[...]  # (B, C, h+2, w+2) padded activations
    mu = mu_ref[...]  # (C, 9)
    sig = sig_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=o_ref.dtype)
    for k in range(NUM_TAPS):
        dy, dx = divmod(k, KERNEL_EDGE)
        wk = (
            mu[None, :, None, None, k]
            + sig[None, :, None, None, k] * eps_ref[..., k]
        )
        acc = acc + wk * xw[:, :, dy : dy + h, dx : dx + w]
    o_ref[...] = acc


def _prob_dws_pallas(x, mu, sigma, eps):
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    kern = functools.partial(_prob_dws_kernel, h=h, w=w)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b, c, h, w), x.dtype),
        interpret=_INTERPRET,
    )(xp, mu, sigma, eps)


@jax.custom_vjp
def prob_depthwise_conv3x3(x, mu, sigma, eps):
    """Probabilistic 3x3 depthwise conv with per-output-element weight noise.

    Args:
      x:     (B, C, H, W) activations.
      mu:    (C, 9) tap means.
      sigma: (C, 9) tap standard deviations (>= 0).
      eps:   (B, C, H, W, 9) unit noise (from the chaotic light source).

    Returns: (B, C, H, W).
    """
    return _prob_dws_pallas(x, mu, sigma, eps)


def _prob_dws_fwd(x, mu, sigma, eps):
    return _prob_dws_pallas(x, mu, sigma, eps), (x, mu, sigma, eps)


def _prob_dws_bwd(res, g):
    x, mu, sigma, eps = res
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    dx = jnp.zeros_like(x)
    dmu = jnp.zeros_like(mu)
    dsig = jnp.zeros_like(sigma)
    deps = jnp.zeros_like(eps)
    for k in range(NUM_TAPS):
        dy, dxo = divmod(k, KERNEL_EDGE)
        win = xp[:, :, dy : dy + h, dxo : dxo + w]  # (B, C, H, W)
        ek = eps[..., k]
        wk = mu[None, :, None, None, k] + sigma[None, :, None, None, k] * ek
        # dL/dx: transpose of the shift — correlation with flipped offsets.
        gk = jnp.pad(wk * g, ((0, 0), (0, 0), (1, 1), (1, 1)))
        dx = dx + gk[:, :, 2 - dy : 2 - dy + h, 2 - dxo : 2 - dxo + w]
        dmu = dmu.at[:, k].add(jnp.sum(g * win, axis=(0, 2, 3)))
        dsig = dsig.at[:, k].add(jnp.sum(g * win * ek, axis=(0, 2, 3)))
        deps = deps.at[..., k].set(g * sigma[None, :, None, None, k] * win)
    return dx, dmu, dsig, deps


prob_depthwise_conv3x3.defvjp(_prob_dws_fwd, _prob_dws_bwd)


# ---------------------------------------------------------------------------
# Pointwise (1x1 over channels) convolution — the second half of the paper's
# Depthwise-Separable block, shaped as a (pixels x C_in) @ (C_in x C_out)
# matmul so a real-TPU lowering would hit the MXU systolic array.
# ---------------------------------------------------------------------------


def _pointwise_kernel(x_ref, w_ref, o_ref):
    # x: (B*HW, C_in); w: (C_in, C_out) resident; one MXU-shaped dot.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype)


def _pointwise_pallas(x, wmat):
    b, c_in, h, w = x.shape
    c_out = wmat.shape[1]
    # single block: (B*HW, C_in) @ (C_in, C_out); the flattened pixel axis is
    # the MXU's long dimension, the weight matrix stays VMEM-resident.
    xr = jnp.transpose(x, (0, 2, 3, 1)).reshape(b * h * w, c_in)
    out = pl.pallas_call(
        _pointwise_kernel,
        out_shape=jax.ShapeDtypeStruct((b * h * w, c_out), x.dtype),
        interpret=_INTERPRET,
    )(xr, wmat)
    return jnp.transpose(out.reshape(b, h, w, c_out), (0, 3, 1, 2))


@jax.custom_vjp
def pointwise_conv(x, wmat):
    """1x1 channel-mixing convolution: (B, C_in, H, W) x (C_in, C_out)."""
    return _pointwise_pallas(x, wmat)


def _pointwise_fwd(x, wmat):
    return _pointwise_pallas(x, wmat), (x, wmat)


def _pointwise_bwd(res, g):
    x, wmat = res
    # y[b,o,i,j] = sum_c x[b,c,i,j] * w[c,o]
    dx = jnp.einsum("boij,co->bcij", g, wmat)
    dw = jnp.einsum("bcij,boij->co", x, g)
    return dx, dw


pointwise_conv.defvjp(_pointwise_fwd, _pointwise_bwd)


# ---------------------------------------------------------------------------
# 8-bit fake quantization (DAC/ADC model) with straight-through estimator
# ---------------------------------------------------------------------------


def _quant_kernel(x_ref, o_ref, *, scale: float):
    x = x_ref[...]
    q = jnp.clip(jnp.round(x * (127.0 / scale)), -128.0, 127.0)
    o_ref[...] = q * (scale / 127.0)


def _quant_pallas(x, scale: float):
    flat = x.reshape(-1)
    out = pl.pallas_call(
        functools.partial(_quant_kernel, scale=scale),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=_INTERPRET,
    )(flat)
    return out.reshape(x.shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant8(x, scale: float):
    """8-bit symmetric fake quantization with a *saturating* straight-through
    estimator: identity gradient inside the converter's full-scale range,
    zero outside.

    Models the machine's 8-bit 80 GSPS DAC (input path) and ADC (readout
    path).  ``scale`` is the full-scale range, a static calibration
    constant.  The saturating STE matters: with an unmasked STE, weights
    that push activations past the ADC range keep receiving gradients as if
    the converter were linear, and SVI training diverges once the
    probabilistic layer's outputs start clipping (observed: loss collapse
    after ~3 epochs; see EXPERIMENTS.md §Perf notes).
    """
    return _quant_pallas(x, scale)


def _quant_fwd(x, scale):
    return _quant_pallas(x, scale), (x,)


def _quant_bwd(scale, res, g):
    (x,) = res
    lo = -128.0 * scale / 127.0
    mask = ((x >= lo) & (x <= scale)).astype(g.dtype)
    return (g * mask,)


fake_quant8.defvjp(_quant_fwd, _quant_bwd)
