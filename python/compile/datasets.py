"""Procedural dataset substrates (paper-data substitution, see DESIGN.md).

The paper evaluates on MedMNIST BloodMNIST (7 blood-cell classes +
erythroblasts held out as OOD) and on MNIST / Ambiguous-MNIST /
Fashion-MNIST.  None of those are available offline, so this module builds
procedural equivalents that preserve the *experimental structure*:

* ``digits``      — 10-class stroke-rendered handwritten-digit analogue
                    (train + ID test set),
* ``ambiguous``   — alpha-blends of two digit renders (the exact
                    construction of Ambiguous-MNIST): factually unclear
                    inputs -> aleatoric uncertainty probe,
* ``fashion``     — procedural garment silhouettes, distributionally
                    disjoint from strokes: epistemic uncertainty probe,
* ``blood``       — 28x28x3 blood-cell microscopy analogue with
                    class-specific morphology (nucleus lobation, granule
                    color/density, cell size); the erythroblast morphology
                    (round dark nucleus + *reddish* cytoplasm) is generated
                    only for the OOD split, mirroring the paper's held-out
                    precursor cell type.

Images are stored as uint8 ``.npy`` (N, C, H, W) plus int32 label vectors;
the Rust side has a matching reader (``rust/src/data/npy.rs``).
"""

from __future__ import annotations

import numpy as np

HW = 28

# Difficulty knobs — tuned so the BNN lands near the paper's ID accuracies
# (blood ~90 %, digits ~96 %) instead of saturating at 100 %.
DIGIT_NOISE = 0.10
DIGIT_JITTER = 0.16
BLOOD_NOISE = 0.055
BLOOD_OCCLUDE_P = 0.22

_YY, _XX = np.meshgrid(np.arange(HW, dtype=np.float32),
                       np.arange(HW, dtype=np.float32), indexing="ij")


# ---------------------------------------------------------------------------
# Digit strokes
# ---------------------------------------------------------------------------

# Normalized [0,1]^2 polyline skeletons (y down).  Multiple strokes per digit.
_DIGIT_STROKES = {
    0: [[(0.5, 0.1), (0.8, 0.3), (0.8, 0.7), (0.5, 0.9), (0.2, 0.7), (0.2, 0.3), (0.5, 0.1)]],
    1: [[(0.35, 0.25), (0.55, 0.1), (0.55, 0.9)]],
    2: [[(0.2, 0.3), (0.4, 0.1), (0.7, 0.15), (0.75, 0.4), (0.25, 0.85), (0.8, 0.85)]],
    3: [[(0.25, 0.15), (0.7, 0.2), (0.5, 0.45), (0.75, 0.65), (0.55, 0.9), (0.22, 0.85)]],
    4: [[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)]],
    5: [[(0.75, 0.12), (0.3, 0.12), (0.28, 0.45), (0.65, 0.45), (0.72, 0.7), (0.5, 0.9), (0.22, 0.82)]],
    6: [[(0.7, 0.12), (0.35, 0.35), (0.25, 0.7), (0.5, 0.9), (0.72, 0.7), (0.55, 0.5), (0.28, 0.62)]],
    7: [[(0.2, 0.12), (0.8, 0.12), (0.45, 0.9)]],
    8: [[(0.5, 0.1), (0.75, 0.25), (0.5, 0.48), (0.25, 0.25), (0.5, 0.1)],
        [(0.5, 0.48), (0.78, 0.7), (0.5, 0.92), (0.22, 0.7), (0.5, 0.48)]],
    9: [[(0.72, 0.38), (0.5, 0.5), (0.28, 0.35), (0.35, 0.12), (0.65, 0.1), (0.72, 0.38), (0.68, 0.9)]],
}


def _resample_polyline(pts: np.ndarray, n: int) -> np.ndarray:
    """Resample a polyline to n equidistant points."""
    seg = np.diff(pts, axis=0)
    seglen = np.sqrt((seg ** 2).sum(1))
    t = np.concatenate([[0.0], np.cumsum(seglen)])
    total = t[-1]
    if total <= 0:
        return np.repeat(pts[:1], n, axis=0)
    u = np.linspace(0, total, n)
    x = np.interp(u, t, pts[:, 0])
    y = np.interp(u, t, pts[:, 1])
    return np.stack([x, y], axis=1)


def _render_strokes(strokes, rng, thickness=None, jitter=DIGIT_JITTER):
    """Rasterize jittered strokes with a Gaussian brush -> (HW, HW) in [0,1]."""
    ang = rng.normal(0.0, 0.18) * jitter / 0.16
    scale = 1.0 + rng.normal(0.0, 0.09)
    shear = rng.normal(0.0, 0.08)
    tx, ty = rng.normal(0.0, 1.3, 2)
    ca, sa = np.cos(ang), np.sin(ang)
    A = np.array([[ca, -sa], [sa + shear, ca]]) * scale
    if thickness is None:
        thickness = rng.uniform(0.9, 1.6)
    img = np.zeros((HW, HW), np.float32)
    for poly in strokes:
        pts = np.asarray(poly, np.float32)
        pts = pts + rng.normal(0.0, 0.02 * jitter / 0.16, pts.shape)
        pts = _resample_polyline(pts, 60)
        xy = (pts - 0.5) * (HW - 8)
        xy = xy @ A.T
        px = xy[:, 0] + HW / 2 + tx
        py = xy[:, 1] + HW / 2 + ty
        d2 = (_XX[None] - px[:, None, None]) ** 2 + (_YY[None] - py[:, None, None]) ** 2
        img = np.maximum(img, np.exp(-d2 / (2 * thickness ** 2)).max(axis=0))
    return img


def _finish_gray(img, rng, noise=DIGIT_NOISE):
    img = img * rng.uniform(0.75, 1.0)
    img = img + rng.normal(0.0, noise, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def gen_digits(n: int, seed: int, noise: float = DIGIT_NOISE):
    """n stroke-digit images -> (x uint8 (n,1,28,28), y int32 (n,))."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, 1, HW, HW), np.uint8)
    y = rng.integers(0, 10, n).astype(np.int32)
    for i in range(n):
        img = _render_strokes(_DIGIT_STROKES[int(y[i])], rng)
        img = _finish_gray(img, rng, noise)
        x[i, 0] = (img * 255).astype(np.uint8)
    return x, y


def gen_ambiguous(n: int, seed: int):
    """Ambiguous digits: alpha-blend two classes (aleatoric probe).

    Returns (x, y_pair) where y_pair[:, 0] and [:, 1] are the blended classes.
    """
    rng = np.random.default_rng(seed)
    x = np.zeros((n, 1, HW, HW), np.uint8)
    pairs = np.zeros((n, 2), np.int32)
    # visually confusable digit pairs (as in Ambiguous-MNIST's construction)
    cand = [(0, 6), (1, 7), (3, 8), (4, 9), (5, 6), (2, 3), (8, 9), (3, 5), (7, 9), (0, 8)]
    for i in range(n):
        a, b = cand[rng.integers(0, len(cand))]
        alpha = rng.uniform(0.38, 0.62)
        ia = _render_strokes(_DIGIT_STROKES[a], rng)
        ib = _render_strokes(_DIGIT_STROKES[b], rng)
        img = np.maximum(alpha * ia, (1 - alpha) * ib)
        img = img / max(img.max(), 1e-6) * rng.uniform(0.8, 1.0)
        img = _finish_gray(img, rng)
        x[i, 0] = (img * 255).astype(np.uint8)
        pairs[i] = (a, b)
    return x, pairs


# ---------------------------------------------------------------------------
# Fashion silhouettes (epistemic probe)
# ---------------------------------------------------------------------------


def _rect(cx, cy, hw, hh):
    return (np.abs(_XX - cx) < hw) & (np.abs(_YY - cy) < hh)


def _ellipse(cx, cy, rx, ry):
    return ((_XX - cx) / max(rx, 1e-3)) ** 2 + ((_YY - cy) / max(ry, 1e-3)) ** 2 < 1.0


def _triangle_down(cx, top, bot, halfw):
    """Triangle widening from (cx, top) down to half-width halfw at bot."""
    frac = np.clip((_YY - top) / max(bot - top, 1e-3), 0, 1)
    return (np.abs(_XX - cx) < halfw * frac) & (_YY >= top) & (_YY <= bot)


def gen_fashion(n: int, seed: int):
    """Procedural garment silhouettes (10 pseudo-classes), uint8 (n,1,28,28)."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, 1, HW, HW), np.uint8)
    y = rng.integers(0, 10, n).astype(np.int32)
    for i in range(n):
        c = int(y[i])
        j = lambda s=1.0: rng.normal(0, s)
        m = np.zeros((HW, HW), bool)
        if c == 0:  # t-shirt
            m = _rect(14 + j(), 16 + j(), 5.5, 8) | _rect(14 + j(), 10 + j(), 10, 2.5)
        elif c == 1:  # trousers
            m = _rect(10.5 + j(0.5), 16 + j(), 2.2, 10) | _rect(17.5 + j(0.5), 16 + j(), 2.2, 10) | _rect(14, 7.5, 5.5, 2)
        elif c == 2:  # pullover
            m = _rect(14 + j(), 16 + j(), 6.5, 8.5) | _rect(6 + j(), 14, 2.2, 6.5) | _rect(22 + j(), 14, 2.2, 6.5)
        elif c == 3:  # dress
            m = _triangle_down(14 + j(), 6 + j(), 24, 8.5) | _rect(14, 6.5, 3, 2.5)
        elif c == 4:  # coat
            m = _rect(14 + j(), 15.5 + j(), 7, 10) | _rect(14, 5.5, 3.5, 1.8)
        elif c == 5:  # sandal
            m = _rect(14 + j(), 20 + j(0.5), 9, 1.6) | _rect(10 + j(), 16, 1.2, 3.5) | _rect(18 + j(), 16, 1.2, 3.5)
        elif c == 6:  # shirt
            m = _rect(14 + j(), 16 + j(), 6, 9) | _rect(14, 8, 9.5, 2) | _rect(14, 14, 0.8, 6)
        elif c == 7:  # sneaker
            m = _rect(14 + j(), 19.5 + j(0.5), 9, 2.6) | _triangle_down(19 + j(), 13.5, 18.5, 4.5)
        elif c == 8:  # bag
            m = _rect(14 + j(), 17 + j(), 8, 6) | (_ellipse(14 + j(), 10.5, 5, 3.5) & ~_ellipse(14, 10.5, 3.4, 2.2))
        else:  # ankle boot
            m = _rect(17 + j(), 20 + j(0.5), 6.5, 2.8) | _rect(12 + j(), 14 + j(), 2.8, 7)
        img = m.astype(np.float32) * rng.uniform(0.7, 1.0)
        img *= 1.0 - 0.35 * rng.random((HW, HW)).astype(np.float32)  # fabric texture
        img = _finish_gray(img, rng, noise=0.06)
        x[i, 0] = (img * 255).astype(np.uint8)
    return x, y


# ---------------------------------------------------------------------------
# Blood cells (BloodMNIST analogue)
# ---------------------------------------------------------------------------

BLOOD_CLASSES = [
    "basophil", "eosinophil", "immature_granulocyte", "lymphocyte",
    "monocyte", "neutrophil", "platelet",
]
BLOOD_OOD_CLASS = "erythroblast"

# morphology table: body radius, cytoplasm RGB, nucleus lobe count range,
# nucleus radius factor, nucleus RGB, granule (density, RGB, size)
_BLOOD_MORPH = {
    "basophil":    dict(r=(7.0, 8.5), cyto=(0.75, 0.70, 0.85), lobes=(2, 2), nucr=0.55,
                        nuc=(0.35, 0.25, 0.55), gran=(0.55, (0.30, 0.15, 0.45), 1.1)),
    "eosinophil":  dict(r=(7.0, 8.5), cyto=(0.95, 0.75, 0.70), lobes=(2, 2), nucr=0.50,
                        nuc=(0.45, 0.30, 0.60), gran=(0.50, (0.90, 0.35, 0.25), 1.0)),
    "immature_granulocyte": dict(r=(8.0, 9.5), cyto=(0.80, 0.82, 0.92), lobes=(1, 1), nucr=0.72,
                        nuc=(0.40, 0.30, 0.62), gran=(0.12, (0.55, 0.45, 0.70), 0.8)),
    "lymphocyte":  dict(r=(5.0, 6.5), cyto=(0.70, 0.78, 0.92), lobes=(1, 1), nucr=0.85,
                        nuc=(0.28, 0.20, 0.52), gran=(0.0, (0, 0, 0), 0)),
    "monocyte":    dict(r=(9.0, 10.5), cyto=(0.78, 0.80, 0.88), lobes=(1, 2), nucr=0.62,
                        nuc=(0.50, 0.42, 0.68), gran=(0.0, (0, 0, 0), 0)),
    "neutrophil":  dict(r=(7.0, 8.5), cyto=(0.92, 0.82, 0.82), lobes=(3, 5), nucr=0.32,
                        nuc=(0.38, 0.28, 0.58), gran=(0.25, (0.85, 0.70, 0.72), 0.7)),
    "platelet":    dict(r=(2.2, 3.4), cyto=(0.72, 0.60, 0.80), lobes=(0, 0), nucr=0.0,
                        nuc=(0, 0, 0), gran=(0.3, (0.55, 0.40, 0.65), 0.5)),
    # OOD: lymphocyte-like round dark nucleus but tell-tale reddish cytoplasm
    "erythroblast": dict(r=(6.0, 7.5), cyto=(0.92, 0.62, 0.60), lobes=(1, 1), nucr=0.70,
                        nuc=(0.30, 0.18, 0.48), gran=(0.0, (0, 0, 0), 0)),
}


def _blood_image(kind: str, rng) -> np.ndarray:
    mph = _BLOOD_MORPH[kind]
    img = np.zeros((3, HW, HW), np.float32)
    # plasma background with tint jitter
    base = np.array([0.96, 0.90, 0.92], np.float32) + rng.normal(0, 0.02, 3).astype(np.float32)
    img += base[:, None, None]
    # faint background erythrocytes (pale red discs)
    for _ in range(rng.integers(2, 6)):
        cx, cy = rng.uniform(0, HW, 2)
        r = rng.uniform(3.0, 4.5)
        mask = _ellipse(cx, cy, r, r * rng.uniform(0.85, 1.15)).astype(np.float32) * 0.5
        col = np.array([0.94, 0.70, 0.68]) + rng.normal(0, 0.02, 3)
        img = img * (1 - mask) + col[:, None, None] * mask
    cx, cy = HW / 2 + rng.normal(0, 1.2), HW / 2 + rng.normal(0, 1.2)
    r = rng.uniform(*mph["r"])
    body = _ellipse(cx, cy, r, r * rng.uniform(0.88, 1.12)).astype(np.float32)
    cyto = np.array(mph["cyto"], np.float32) + rng.normal(0, 0.03, 3).astype(np.float32)
    img = img * (1 - body) + cyto[:, None, None] * body
    # nucleus lobes
    lo, hi = mph["lobes"]
    nlobe = int(rng.integers(lo, hi + 1)) if hi > 0 else 0
    if nlobe > 0:
        nucr = mph["nucr"] * r
        ncol = np.array(mph["nuc"], np.float32) + rng.normal(0, 0.03, 3).astype(np.float32)
        for li in range(nlobe):
            if nlobe == 1:
                lx, ly = cx + rng.normal(0, 0.8), cy + rng.normal(0, 0.8)
                lr = nucr
            else:
                ang = 2 * np.pi * li / nlobe + rng.uniform(0, 2 * np.pi / nlobe)
                rad = r * rng.uniform(0.25, 0.45)
                lx, ly = cx + rad * np.cos(ang), cy + rad * np.sin(ang)
                lr = nucr * rng.uniform(0.9, 1.3)
            m = _ellipse(lx, ly, lr, lr * rng.uniform(0.8, 1.2)).astype(np.float32) * body
            img = img * (1 - m) + ncol[:, None, None] * m
    # granules
    dens, gcol, gsize = mph["gran"]
    if dens > 0:
        ng = int(dens * r * r)
        gcol = np.asarray(gcol, np.float32)
        for _ in range(ng):
            ang, rad = rng.uniform(0, 2 * np.pi), r * np.sqrt(rng.uniform(0, 1)) * 0.9
            gx, gy = cx + rad * np.cos(ang), cy + rad * np.sin(ang)
            m = _ellipse(gx, gy, gsize, gsize).astype(np.float32) * 0.8
            img = img * (1 - m) + gcol[:, None, None] * m
    return img


def _finish_blood(img, rng):
    # illumination, blur, sensor noise, occasional occlusion (aleatoric noise)
    img = img * rng.uniform(0.8, 1.05)
    # cheap 3x3 binomial blur
    k = np.array([0.25, 0.5, 0.25], np.float32)
    img = np.apply_along_axis(lambda v: np.convolve(v, k, mode="same"), 1, img)
    img = np.apply_along_axis(lambda v: np.convolve(v, k, mode="same"), 2, img)
    img = img + rng.normal(0, BLOOD_NOISE, img.shape).astype(np.float32)
    if rng.random() < BLOOD_OCCLUDE_P:
        w0 = rng.integers(0, HW - 5)
        img[:, :, w0 : w0 + rng.integers(2, 5)] *= rng.uniform(0.3, 0.65)
    return np.clip(img, 0.0, 1.0)


def gen_blood(n: int, seed: int, ood: bool = False):
    """Blood-cell analogue images.

    ood=False -> 7 ID classes, labels 0..6; ood=True -> erythroblasts, label 7.
    """
    rng = np.random.default_rng(seed)
    x = np.zeros((n, 3, HW, HW), np.uint8)
    if ood:
        y = np.full(n, 7, np.int32)
        kinds = [BLOOD_OOD_CLASS] * n
    else:
        y = rng.integers(0, 7, n).astype(np.int32)
        kinds = [BLOOD_CLASSES[int(c)] for c in y]
    for i in range(n):
        img = _finish_blood(_blood_image(kinds[i], rng), rng)
        x[i] = (img * 255).astype(np.uint8)
    return x, y
