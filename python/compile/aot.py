"""AOT export: lower the L2 model to HLO *text* artifacts + data + metadata.

This is the single build-time entry point (``make artifacts``).  It runs
Python exactly once; afterwards the Rust binary is self-contained:

  artifacts/
    digits/   meta.json, params_init.bin, fwd_pre_b*.hlo.txt,
              fwd_post_b*.hlo.txt, fwd_full_b*.hlo.txt, train_step.hlo.txt
    blood/    (same, 3 input channels / 7 classes)
    data/     *.npy procedural datasets (see datasets.py)
    MANIFEST.json

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` rust crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, model

PRE_BATCHES = [1, 8, 32]
POST_BATCHES = [1, 8, 32]
FULL_BATCHES = [1, 8, 32, 100]
TRAIN_BATCH = 64

DATASET_CFG = {
    "digits": dict(in_channels=1, n_classes=10),
    "blood": dict(in_channels=3, n_classes=7),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def export_model(outdir: str, name: str, in_channels: int, n_classes: int) -> dict:
    """Lower every entry point for one dataset configuration."""
    ddir = os.path.join(outdir, name)
    os.makedirs(ddir, exist_ok=True)
    n = model.num_params(in_channels, n_classes)
    theta_s = _spec((n,))
    arts = {}

    def dump(fname: str, lowered) -> None:
        text = to_hlo_text(lowered)
        path = os.path.join(ddir, fname)
        with open(path, "w") as f:
            f.write(text)
        arts[fname[: -len(".hlo.txt")]] = fname
        print(f"  [{name}] {fname}: {len(text) / 1024:.0f} KiB")

    for b in PRE_BATCHES:
        fn = lambda t, x: (model.fwd_pre(t, x, in_channels, n_classes),)
        dump(f"fwd_pre_b{b}.hlo.txt",
             jax.jit(fn).lower(theta_s, _spec((b, in_channels, model.IMG_HW, model.IMG_HW))))

    act = (model.PROB_CH, model.PROB_HW, model.PROB_HW)
    for b in POST_BATCHES:
        fn = lambda t, x3q, d3: (model.fwd_post(t, x3q, d3, in_channels, n_classes),)
        dump(f"fwd_post_b{b}.hlo.txt",
             jax.jit(fn).lower(theta_s, _spec((b,) + act), _spec((b,) + act)))

    eps_shape = (model.PROB_CH, model.PROB_HW, model.PROB_HW, 9)
    for b in FULL_BATCHES:
        fn = lambda t, x, e: (model.fwd_full(t, x, e, in_channels, n_classes),)
        dump(f"fwd_full_b{b}.hlo.txt",
             jax.jit(fn).lower(theta_s,
                               _spec((b, in_channels, model.IMG_HW, model.IMG_HW)),
                               _spec((b,) + eps_shape)))

    fn = lambda t, m, v, s, x, y, e, ks, lr: model.train_step(
        t, m, v, s, x, y, e, ks, lr, in_channels, n_classes)
    dump("train_step.hlo.txt",
         jax.jit(fn).lower(
             theta_s, theta_s, theta_s, _spec((), jnp.float32),
             _spec((TRAIN_BATCH, in_channels, model.IMG_HW, model.IMG_HW)),
             _spec((TRAIN_BATCH,), jnp.int32),
             _spec((TRAIN_BATCH,) + eps_shape),
             _spec((), jnp.float32), _spec((), jnp.float32)))

    theta0 = model.init_params(seed=1234, in_channels=in_channels, n_classes=n_classes)
    theta0.astype("<f4").tofile(os.path.join(ddir, "params_init.bin"))

    meta = {
        "dataset": name,
        "in_channels": in_channels,
        "n_classes": n_classes,
        "img_hw": model.IMG_HW,
        "prob_ch": model.PROB_CH,
        "prob_hw": model.PROB_HW,
        "num_taps": 9,
        "feat_ch": model.FEAT_CH,
        "num_params": n,
        "scale_dac": model.SCALE_DAC,
        "scale_adc": model.SCALE_ADC,
        "prior_sigma": model.PRIOR_SIGMA,
        "rho_init": model.RHO_INIT,
        "min_rel_sigma": model.MIN_REL_SIGMA,
        "t_symbol_ps": model.T_SYMBOL_PS,
        "bw_range_ghz": [model.BW_MIN_GHZ, model.BW_MAX_GHZ],
        "batch_sizes": {"pre": PRE_BATCHES, "post": POST_BATCHES,
                        "full": FULL_BATCHES, "train": TRAIN_BATCH},
        "param_layout": [
            {"name": s.name, "shape": list(s.shape), "offset": s.offset, "size": s.size}
            for s in model.param_layout(in_channels, n_classes)
        ],
        "artifacts": arts,
    }
    with open(os.path.join(ddir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def export_data(outdir: str) -> None:
    ddir = os.path.join(outdir, "data")
    os.makedirs(ddir, exist_ok=True)

    def save(stem, x, y):
        np.save(os.path.join(ddir, stem + "_x.npy"), x)
        np.save(os.path.join(ddir, stem + "_y.npy"), y)
        print(f"  data/{stem}: x{list(x.shape)} y{list(y.shape)}")

    t0 = time.time()
    save("digits_train", *datasets.gen_digits(8000, seed=11))
    save("digits_test", *datasets.gen_digits(2000, seed=12))
    save("ambiguous", *datasets.gen_ambiguous(1500, seed=13))
    save("fashion", *datasets.gen_fashion(1500, seed=14))
    save("blood_train", *datasets.gen_blood(8000, seed=15))
    save("blood_test", *datasets.gen_blood(1500, seed=16))
    save("blood_ood", *datasets.gen_blood(1000, seed=17, ood=True))
    print(f"  data generated in {time.time() - t0:.1f}s")


def source_digest() -> str:
    """Hash of the compile-path sources, stored in MANIFEST.json so `make`
    can skip regeneration when nothing changed."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _dirs, files in os.walk(here):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--datasets", default="digits,blood")
    ap.add_argument("--skip-data", action="store_true")
    ap.add_argument("--skip-models", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    manifest = {"source_digest": source_digest(), "models": {}}
    if not args.skip_models:
        for name in args.datasets.split(","):
            cfg = DATASET_CFG[name]
            print(f"exporting model artifacts for '{name}' ...")
            meta = export_model(args.outdir, name, **cfg)
            manifest["models"][name] = {"num_params": meta["num_params"]}
    if not args.skip_data:
        print("generating datasets ...")
        export_data(args.outdir)
    with open(os.path.join(args.outdir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("AOT export complete.")


if __name__ == "__main__":
    main()
