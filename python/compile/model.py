"""L2 — the paper's hybrid Bayesian Neural Network in JAX.

Architecture (paper Fig. 3, approximated: the supplement with exact layer
widths is not available, so widths are chosen to keep the same structure):

  stem 3x3 conv (C_in -> 16), ReLU
  Block A : DWS conv (depthwise 3x3 + pointwise 16->16), ReLU,
            concat-skip (DenseNet-style, channel concat) -> 32, avgpool 2x2
  Block B : DWS conv (32 -> 32), ReLU, concat-skip -> 64, avgpool 2x2
  Block P : **probabilistic** DWS block (the blue block of Fig. 3):
            DAC-quantize -> probabilistic depthwise 3x3 (Gaussian taps,
            executed by the photonic Bayesian machine at serving time) ->
            ADC-quantize -> pointwise 64->32, ReLU, concat-skip -> 96
  global average pool -> linear (96 -> n_classes)

Exactly one layer is stochastic (15): the depthwise 3x3 of Block P, whose
(C, 9) taps map one-to-one onto the machine's nine spectral weight channels
(one 3x3 kernel programmed per channel, channels time-multiplexed).

The variational posterior is a diagonal Gaussian per tap: w ~ N(mu,
softplus(rho)^2), trained by Stochastic Variational Inference (21): ELBO =
E_q[NLL] + beta * KL(q || N(0, prior_sigma^2)).  Sampling uses the
reparameterization trick with *externally supplied* noise ``eps`` — at
training time a PRNG, at serving time the chaotic-light entropy source —
drawn per output element, matching the physics (each 37.5 ps convolution
window sees an independent weight sample).

Everything here is build-time only: ``aot.py`` lowers `fwd_pre`, `fwd_post`,
`fwd_full`, and `train_step` to HLO text executed by the Rust runtime.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.photonic_conv import (
    fake_quant8,
    pointwise_conv,
    prob_depthwise_conv3x3,
)
from .kernels.ref import NUM_TAPS

# ---------------------------------------------------------------------------
# Static architecture constants (recorded in artifacts/<ds>/meta.json)
# ---------------------------------------------------------------------------

STEM_CH = 16          # stem output channels
BLOCK_A_CH = STEM_CH              # 16 -> concat 32
BLOCK_B_CH = 2 * STEM_CH          # 32 -> concat 64
PROB_CH = 4 * STEM_CH             # 64 probabilistic depthwise channels
PROB_PW_CH = 2 * STEM_CH          # pointwise after the photonic stage
FEAT_CH = PROB_CH + PROB_PW_CH    # 96 features into the linear head
IMG_HW = 28
PROB_HW = IMG_HW // 4             # 7x7 maps enter the photonic stage

#: DAC full-scale for activations entering the photonic machine.
SCALE_DAC = 4.0
#: ADC full-scale for the photodetector readout.
SCALE_ADC = 8.0
#: Prior stddev of the Gaussian prior over probabilistic taps.
PRIOR_SIGMA = 0.35
#: Initial rho (softplus^-1 of the initial posterior sigma ~ 0.05).
RHO_INIT = -3.0
#: Symbol period of the machine: 3 samples at 80 GSPS (paper: 37.5 ps/conv).
T_SYMBOL_PS = 37.5
#: Channel bandwidth programming range (paper: 25-150 GHz).
BW_MIN_GHZ, BW_MAX_GHZ = 25.0, 150.0
#: Hardware floor on the relative tap noise: a chaotic channel of bandwidth B
#: integrated over one symbol has M = B*T + 1 degrees of freedom, so the
#: machine cannot realize sigma below |mu| / sqrt(1 + B_max*T).  The forward
#: pass clamps to this floor with a straight-through estimator ("simulate the
#: limited hardware accuracy during the forward pass, while gradients remain
#: unaffected" — paper, Methods).
MIN_REL_SIGMA = float(1.0 / np.sqrt(1.0 + BW_MAX_GHZ * 1e9 * T_SYMBOL_PS * 1e-12))
#: L2 coefficient on deterministic (point-estimate) parameters.
DET_WEIGHT_DECAY = 1e-4


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One named parameter inside the flat parameter vector."""

    name: str
    shape: Tuple[int, ...]
    offset: int

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def param_layout(in_channels: int, n_classes: int) -> List[ParamSpec]:
    """Flat-vector layout of all trainable parameters.

    The whole parameter state is a single f32 vector so the Rust side stays
    schema-free: it round-trips one array and lets HLO unpack it with static
    slices.  Order matters and is mirrored in ``artifacts/<ds>/meta.json``.
    """
    specs: List[ParamSpec] = []
    off = 0

    def add(name: str, shape: Tuple[int, ...]) -> None:
        nonlocal off
        specs.append(ParamSpec(name, shape, off))
        off += int(np.prod(shape))

    add("stem_w", (STEM_CH, in_channels, 3, 3))
    add("stem_b", (STEM_CH,))
    add("dw1", (BLOCK_A_CH, NUM_TAPS))
    add("pw1", (BLOCK_A_CH, BLOCK_A_CH))
    add("b1", (BLOCK_A_CH,))
    add("dw2", (BLOCK_B_CH, NUM_TAPS))
    add("pw2", (BLOCK_B_CH, BLOCK_B_CH))
    add("b2", (BLOCK_B_CH,))
    add("prob_mu", (PROB_CH, NUM_TAPS))
    add("prob_rho", (PROB_CH, NUM_TAPS))
    add("pw3", (PROB_CH, PROB_PW_CH))
    add("b3", (PROB_PW_CH,))
    add("fc_w", (FEAT_CH, n_classes))
    add("fc_b", (n_classes,))
    return specs


def num_params(in_channels: int, n_classes: int) -> int:
    specs = param_layout(in_channels, n_classes)
    return specs[-1].offset + specs[-1].size


def unpack(theta: jnp.ndarray, in_channels: int, n_classes: int) -> Dict[str, jnp.ndarray]:
    """Static-slice the flat vector into named parameter arrays."""
    out = {}
    for s in param_layout(in_channels, n_classes):
        out[s.name] = jax.lax.dynamic_slice(theta, (s.offset,), (s.size,)).reshape(s.shape)
    return out


def init_params(seed: int, in_channels: int, n_classes: int) -> np.ndarray:
    """He-style initialization of the flat parameter vector (numpy, build time)."""
    rng = np.random.default_rng(seed)
    specs = param_layout(in_channels, n_classes)
    theta = np.zeros(num_params(in_channels, n_classes), dtype=np.float32)
    for s in specs:
        if s.name.endswith("_b") or s.name in ("b1", "b2", "b3"):
            vals = np.zeros(s.shape, np.float32)
        elif s.name == "prob_mu":
            # fan_in of a depthwise 3x3 tap group is 9
            vals = rng.normal(0.0, np.sqrt(2.0 / NUM_TAPS), s.shape).astype(np.float32)
        elif s.name == "prob_rho":
            vals = np.full(s.shape, RHO_INIT, np.float32)
        else:
            fan_in = int(np.prod(s.shape[1:])) if len(s.shape) > 1 else s.shape[0]
            vals = rng.normal(0.0, np.sqrt(2.0 / max(fan_in, 1)), s.shape).astype(np.float32)
    # note: fc fan-in is s.shape[0]; handled by generic branch closely enough
        theta[s.offset : s.offset + s.size] = vals.ravel()
    return theta


# ---------------------------------------------------------------------------
# Deterministic building blocks
# ---------------------------------------------------------------------------


def ste_sigma_floor(sigma: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Clamp sigma to the machine's hardware floor, straight-through gradient."""
    clamped = jnp.maximum(sigma, MIN_REL_SIGMA * jnp.abs(mu))
    return sigma + jax.lax.stop_gradient(clamped - sigma)


def _conv3x3(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Standard 3x3 SAME conv, NCHW / OIHW."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _depthwise3x3(x: jnp.ndarray, taps: jnp.ndarray) -> jnp.ndarray:
    """Deterministic fully-grouped 3x3 conv via static shifts (taps: (C, 9))."""
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    out = jnp.zeros_like(x)
    for k in range(NUM_TAPS):
        dy, dx = divmod(k, 3)
        out = out + taps[None, :, None, None, k] * xp[:, :, dy : dy + h, dx : dx + w]
    return out


def _avgpool2(x: jnp.ndarray) -> jnp.ndarray:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).mean(axis=(3, 5))


def _dws_block(x: jnp.ndarray, dw: jnp.ndarray, pw: jnp.ndarray, bias: jnp.ndarray,
               pool: bool) -> jnp.ndarray:
    """Depthwise-separable block with DenseNet concat skip (Fig. 3)."""
    h = _depthwise3x3(x, dw)
    h = pointwise_conv(h, pw) + bias[None, :, None, None]
    h = jax.nn.relu(h)
    out = jnp.concatenate([x, h], axis=1)
    return _avgpool2(out) if pool else out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def fwd_pre(theta: jnp.ndarray, x: jnp.ndarray, in_channels: int, n_classes: int) -> jnp.ndarray:
    """Deterministic layers *before* the photonic stage.

    Returns the DAC-quantized (B, PROB_CH, 7, 7) activations that are
    time-encoded onto the machine's spectral channels at serving time.
    """
    p = unpack(theta, in_channels, n_classes)
    h = jax.nn.relu(_conv3x3(x, p["stem_w"], p["stem_b"]))
    h = _dws_block(h, p["dw1"], p["pw1"], p["b1"], pool=True)   # (B, 32, 14, 14)
    h = _dws_block(h, p["dw2"], p["pw2"], p["b2"], pool=True)   # (B, 64, 7, 7)
    return fake_quant8(h, SCALE_DAC)


def fwd_post(theta: jnp.ndarray, x3q: jnp.ndarray, d3: jnp.ndarray,
             in_channels: int, n_classes: int) -> jnp.ndarray:
    """Deterministic layers *after* the photonic stage.

    Args:
      x3q: (B, PROB_CH, 7, 7) the photonic stage's input (for the concat skip).
      d3:  (B, PROB_CH, 7, 7) the machine's readout (already ADC-quantized by
           the hardware; the surrogate path quantizes before calling this).
    """
    p = unpack(theta, in_channels, n_classes)
    h = pointwise_conv(d3, p["pw3"]) + p["b3"][None, :, None, None]
    h = jax.nn.relu(h)
    h = jnp.concatenate([x3q, h], axis=1)          # (B, 96, 7, 7)
    feat = h.mean(axis=(2, 3))                      # global average pool
    return feat @ p["fc_w"] + p["fc_b"]


def fwd_full(theta: jnp.ndarray, x: jnp.ndarray, eps: jnp.ndarray,
             in_channels: int, n_classes: int) -> jnp.ndarray:
    """Full surrogate forward (training / surrogate-serving path).

    The probabilistic depthwise conv runs as the L1 Pallas kernel with
    reparameterized Gaussian taps; DAC/ADC quantization is modeled with
    straight-through estimators so gradients are unaffected (paper, Methods).
    """
    p = unpack(theta, in_channels, n_classes)
    x3q = fwd_pre(theta, x, in_channels, n_classes)
    sigma = ste_sigma_floor(jax.nn.softplus(p["prob_rho"]), p["prob_mu"])
    d3 = prob_depthwise_conv3x3(x3q, p["prob_mu"], sigma, eps)
    d3q = fake_quant8(d3, SCALE_ADC)
    return fwd_post(theta, x3q, d3q, in_channels, n_classes)


# ---------------------------------------------------------------------------
# SVI training step (ELBO + Adam), exported as a single HLO
# ---------------------------------------------------------------------------


def _kl_gauss(mu: jnp.ndarray, sigma: jnp.ndarray, prior_sigma: float) -> jnp.ndarray:
    """KL( N(mu, sigma^2) || N(0, prior_sigma^2) ), summed over taps."""
    var_ratio = (sigma / prior_sigma) ** 2
    return 0.5 * jnp.sum(var_ratio + (mu / prior_sigma) ** 2 - 1.0 - jnp.log(var_ratio))


def _det_l2(p: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    tot = 0.0
    for name, v in p.items():
        if name not in ("prob_mu", "prob_rho"):
            tot = tot + jnp.sum(v * v)
    return tot


def loss_fn(theta, x, y, eps, kl_scale, in_channels, n_classes):
    """beta-ELBO: mean NLL + kl_scale * KL + weight decay on point params."""
    logits = fwd_full(theta, x, eps, in_channels, n_classes)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    p = unpack(theta, in_channels, n_classes)
    kl = _kl_gauss(p["prob_mu"], jax.nn.softplus(p["prob_rho"]), PRIOR_SIGMA)
    loss = nll + kl_scale * kl + DET_WEIGHT_DECAY * _det_l2(p)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, (nll, kl, acc)


def train_step(theta, m, v, step, x, y, eps, kl_scale, lr,
               in_channels: int, n_classes: int):
    """One Adam step on the beta-ELBO.  All state flows through arguments so
    the Rust trainer owns the loop; returns (theta', m', v', loss, nll, kl, acc).
    """
    grad_fn = jax.value_and_grad(
        lambda t: loss_fn(t, x, y, eps, kl_scale, in_channels, n_classes),
        has_aux=True,
    )
    (loss, (nll, kl, acc)), g = grad_fn(theta)
    b1, b2, eps_adam = 0.9, 0.999, 1e-8
    step = step + 1.0
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * g * g
    mhat = m / (1.0 - b1 ** step)
    vhat = v / (1.0 - b2 ** step)
    theta = theta - lr * mhat / (jnp.sqrt(vhat) + eps_adam)
    return theta, m, v, loss, nll, kl, acc


def eval_batch(theta, x, eps, in_channels: int, n_classes: int):
    """Surrogate-mode eval: returns per-sample logits (softmax done in Rust)."""
    return fwd_full(theta, x, eps, in_channels, n_classes)
