"""L1 correctness: Pallas kernels vs the pure-jnp oracle (``ref.py``).

This is the CORE correctness signal for the photonic machine's compute
model: hypothesis sweeps shapes/dtypes/parameter ranges and asserts
allclose between the interpret-mode Pallas kernel and the reference.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import photonic_conv as pk
from compile.kernels import ref

hypothesis.settings.register_profile(
    "kernels", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow],
)
hypothesis.settings.load_profile("kernels")


def _rand(rng, shape, lo=-2.0, hi=2.0):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# prob_depthwise_conv3x3
# ---------------------------------------------------------------------------


@hypothesis.given(
    b=st.integers(1, 4),
    c=st.integers(1, 8),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_prob_dws_matches_ref(b, c, h, w, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, c, h, w))
    mu = _rand(rng, (c, 9), -1, 1)
    sigma = _rand(rng, (c, 9), 0.0, 0.5)
    eps = _rand(rng, (b, c, h, w, 9), -3, 3)
    got = pk.prob_depthwise_conv3x3(x, mu, sigma, eps)
    want = ref.prob_depthwise_conv3x3_ref(x, mu, sigma, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_prob_dws_zero_sigma_is_deterministic():
    """With sigma == 0 the probabilistic conv equals the deterministic one."""
    rng = np.random.default_rng(0)
    x = _rand(rng, (2, 3, 7, 7))
    mu = _rand(rng, (3, 9))
    eps = _rand(rng, (2, 3, 7, 7, 9), -5, 5)
    got = pk.prob_depthwise_conv3x3(x, mu, jnp.zeros((3, 9)), eps)
    want = ref.depthwise_conv3x3_ref(x, mu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_prob_dws_noise_scales_with_sigma():
    """Output variance across noise draws grows with sigma (physics knob)."""
    rng = np.random.default_rng(1)
    x = jnp.ones((1, 1, 7, 7))
    mu = jnp.zeros((1, 9))
    outs = []
    for s in (0.05, 0.2):
        sigma = jnp.full((1, 9), s)
        draws = []
        for i in range(64):
            eps = _rand(np.random.default_rng(i), (1, 1, 7, 7, 9), -3, 3)
            draws.append(np.asarray(pk.prob_depthwise_conv3x3(x, mu, sigma, eps)))
        outs.append(np.std(np.stack(draws)))
    assert outs[1] > 2.5 * outs[0]


def test_prob_dws_linear_in_input():
    rng = np.random.default_rng(2)
    x = _rand(rng, (1, 2, 5, 5))
    mu, sigma = _rand(rng, (2, 9)), _rand(rng, (2, 9), 0, 0.3)
    eps = _rand(rng, (1, 2, 5, 5, 9))
    y1 = pk.prob_depthwise_conv3x3(x, mu, sigma, eps)
    y2 = pk.prob_depthwise_conv3x3(2.0 * x, mu, sigma, eps)
    np.testing.assert_allclose(2.0 * y1, y2, rtol=1e-5, atol=1e-5)


def test_prob_dws_gradients_match_fd():
    """custom_vjp backward pass vs central finite differences."""
    rng = np.random.default_rng(3)
    x = _rand(rng, (1, 2, 4, 4))
    mu = _rand(rng, (2, 9), -0.5, 0.5)
    sigma = _rand(rng, (2, 9), 0.05, 0.3)
    eps = _rand(rng, (1, 2, 4, 4, 9))

    def f(x_, mu_, sigma_):
        return jnp.sum(jnp.sin(pk.prob_depthwise_conv3x3(x_, mu_, sigma_, eps)))

    gx, gmu, gs = jax.grad(f, argnums=(0, 1, 2))(x, mu, sigma)
    delta = 1e-3
    for (g, arg, idx) in [
        (gx, 0, (0, 1, 2, 2)),
        (gmu, 1, (1, 4)),
        (gs, 2, (0, 7)),
    ]:
        args = [x, mu, sigma]
        pert = np.zeros(args[arg].shape, np.float32)
        pert[idx] = delta
        pert = jnp.asarray(pert)
        hi = f(*[a + pert if i == arg else a for i, a in enumerate(args)])
        lo = f(*[a - pert if i == arg else a for i, a in enumerate(args)])
        fd = float((hi - lo) / (2 * delta))
        assert abs(fd - float(g[idx])) < 5e-2, (arg, idx, fd, float(g[idx]))


def test_prob_dws_grad_eps_equals_sigma_times_window():
    """Analytic identity: dL/deps_k = sigma_k * shifted-input * upstream."""
    rng = np.random.default_rng(4)
    x = _rand(rng, (1, 1, 4, 4))
    mu = jnp.zeros((1, 9))
    sigma = jnp.full((1, 9), 0.5)
    eps = _rand(rng, (1, 1, 4, 4, 9))
    g = jax.grad(lambda e: jnp.sum(pk.prob_depthwise_conv3x3(x, mu, sigma, e)))(eps)
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    for k in range(9):
        dy, dx = divmod(k, 3)
        want = 0.5 * xp[:, :, dy : dy + 4, dx : dx + 4]
        np.testing.assert_allclose(g[..., k], want, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# pointwise_conv
# ---------------------------------------------------------------------------


@hypothesis.given(
    b=st.integers(1, 3),
    cin=st.integers(1, 12),
    cout=st.integers(1, 12),
    hw=st.integers(2, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_pointwise_matches_einsum(b, cin, cout, hw, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, cin, hw, hw))
    w = _rand(rng, (cin, cout))
    got = pk.pointwise_conv(x, w)
    want = jnp.einsum("bcij,co->boij", x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pointwise_grads():
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 3, 4, 4))
    w = _rand(rng, (3, 5))
    f = lambda x_, w_: jnp.sum(pk.pointwise_conv(x_, w_) ** 2)
    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    f_ref = lambda x_, w_: jnp.sum(jnp.einsum("bcij,co->boij", x_, w_) ** 2)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, gx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gw, gw_r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fake_quant8 (DAC/ADC model)
# ---------------------------------------------------------------------------


@hypothesis.given(
    n=st.integers(1, 200),
    scale=st.floats(0.5, 16.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_matches_ref(n, scale, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n,), -2 * scale, 2 * scale)
    got = pk.fake_quant8(x, scale)
    want = ref.fake_quant8_ref(x, scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_quant_is_8bit():
    """Quantized values take at most 256 distinct levels."""
    x = jnp.linspace(-5, 5, 4001)
    q = np.asarray(pk.fake_quant8(x, 4.0))
    assert len(np.unique(q)) <= 256


def test_quant_error_bounded_in_range():
    x = jnp.linspace(-3.99, 3.99, 997)
    q = pk.fake_quant8(x, 4.0)
    assert float(jnp.max(jnp.abs(q - x))) <= 4.0 / 127.0 / 2 + 1e-6


def test_quant_ste_gradient_saturating():
    """Identity gradient inside the converter range, zero where clipped."""
    x = jnp.asarray([-10.0, -0.3, 0.0, 0.7, 10.0])
    g = jax.grad(lambda x_: jnp.sum(pk.fake_quant8(x_, 4.0) * 3.0))(x)
    np.testing.assert_allclose(g, [0.0, 3.0, 3.0, 3.0, 0.0], rtol=1e-6)


def test_quant_clips_out_of_range():
    q = pk.fake_quant8(jnp.asarray([100.0, -100.0]), 4.0)
    np.testing.assert_allclose(q, [4.0, -128.0 * 4.0 / 127.0], rtol=1e-5)
