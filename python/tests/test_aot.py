"""AOT export tests: HLO text round-trip through the XLA client, metadata
consistency, and numerical agreement between the exported computation and
the live jax function (the contract the Rust runtime relies on)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "MANIFEST.json")),
    reason="run `make artifacts` first",
)


def _compile_text(text):
    """Round-trip HLO text through the parser (as the Rust loader does) and
    compile it on the CPU client: text -> HloModule -> XlaComputation ->
    MLIR -> LoadedExecutable."""
    backend = jax.devices("cpu")[0].client
    mod = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(mod.as_serialized_hlo_module_proto())
    mlir_text = xc._xla.mlir.xla_computation_to_mlir_module(comp)
    return backend, backend.compile_and_load(mlir_text, backend.local_devices())


def test_to_hlo_text_produces_parseable_module():
    fn = lambda a, b: (jnp.dot(a, b) + 1.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None


@needs_artifacts
def test_manifest_and_meta_consistent():
    with open(os.path.join(ARTIFACTS, "MANIFEST.json")) as f:
        manifest = json.load(f)
    for name, info in manifest["models"].items():
        with open(os.path.join(ARTIFACTS, name, "meta.json")) as f:
            meta = json.load(f)
        assert meta["num_params"] == info["num_params"]
        cfg = aot.DATASET_CFG[name]
        assert meta["in_channels"] == cfg["in_channels"]
        assert meta["n_classes"] == cfg["n_classes"]
        last = meta["param_layout"][-1]
        assert last["offset"] + last["size"] == meta["num_params"]
        # every artifact listed must exist
        for fname in meta["artifacts"].values():
            assert os.path.exists(os.path.join(ARTIFACTS, name, fname)), fname


@needs_artifacts
def test_params_init_matches_layout():
    for name in ("digits", "blood"):
        with open(os.path.join(ARTIFACTS, name, "meta.json")) as f:
            meta = json.load(f)
        raw = np.fromfile(os.path.join(ARTIFACTS, name, "params_init.bin"), "<f4")
        assert raw.shape[0] == meta["num_params"]
        # prob_rho region must equal RHO_INIT (softplus^-1 of init sigma)
        spec = next(s for s in meta["param_layout"] if s["name"] == "prob_rho")
        region = raw[spec["offset"] : spec["offset"] + spec["size"]]
        np.testing.assert_allclose(region, meta["rho_init"], atol=1e-6)


@needs_artifacts
def test_exported_fwd_full_matches_live_jax():
    """Execute the exported HLO text via the XLA CPU client and compare with
    the live jax function — the exact contract the Rust runtime depends on."""
    name, ic, nc = "digits", 1, 10
    with open(os.path.join(ARTIFACTS, name, "fwd_full_b8.hlo.txt")) as f:
        text = f.read()
    backend, exe = _compile_text(text)

    theta = np.asarray(model.init_params(77, ic, nc))
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 1, (8, ic, 28, 28)).astype(np.float32)
    eps = rng.normal(0, 1, (8, model.PROB_CH, 7, 7, 9)).astype(np.float32)

    out = exe.execute([backend.buffer_from_pyval(v) for v in (theta, x, eps)])
    r = out[0]
    got = np.asarray(r[0] if isinstance(r, (list, tuple)) else r)
    want = np.asarray(model.fwd_full(jnp.asarray(theta), jnp.asarray(x),
                                     jnp.asarray(eps), ic, nc))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@needs_artifacts
def test_data_files_exist_with_expected_shapes():
    ddir = os.path.join(ARTIFACTS, "data")
    expect = {
        "digits_train_x.npy": (8000, 1, 28, 28),
        "digits_test_x.npy": (2000, 1, 28, 28),
        "ambiguous_x.npy": (1500, 1, 28, 28),
        "fashion_x.npy": (1500, 1, 28, 28),
        "blood_train_x.npy": (8000, 3, 28, 28),
        "blood_test_x.npy": (1500, 3, 28, 28),
        "blood_ood_x.npy": (1000, 3, 28, 28),
    }
    for fname, shape in expect.items():
        arr = np.load(os.path.join(ddir, fname))
        assert arr.shape == shape, fname
        assert arr.dtype == np.uint8


def test_source_digest_stable():
    assert aot.source_digest() == aot.source_digest()
