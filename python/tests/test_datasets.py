"""Dataset substrate tests: determinism, shapes, class separability."""

import numpy as np
import pytest

from compile import datasets


def test_digits_shapes_and_range():
    x, y = datasets.gen_digits(32, seed=0)
    assert x.shape == (32, 1, 28, 28) and x.dtype == np.uint8
    assert y.shape == (32,) and y.dtype == np.int32
    assert y.min() >= 0 and y.max() <= 9
    assert x.max() > 100  # strokes actually rendered


def test_digits_deterministic_by_seed():
    x1, y1 = datasets.gen_digits(16, seed=42)
    x2, y2 = datasets.gen_digits(16, seed=42)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = datasets.gen_digits(16, seed=43)
    assert not np.array_equal(x1, x3)


def test_digits_all_classes_reachable():
    _, y = datasets.gen_digits(500, seed=1)
    assert set(np.unique(y)) == set(range(10))


def test_digits_class_templates_distinct():
    """Mean images of different classes must differ clearly (separability)."""
    x, y = datasets.gen_digits(800, seed=2)
    means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            d = np.abs(means[a] - means[b]).mean()
            assert d > 2.0, (a, b, d)


def test_ambiguous_blends_two_classes():
    x, pairs = datasets.gen_ambiguous(64, seed=3)
    assert x.shape == (64, 1, 28, 28)
    assert pairs.shape == (64, 2)
    assert np.all(pairs[:, 0] != pairs[:, 1])


def test_fashion_distinct_from_digits():
    """Fashion silhouettes occupy much more area than digit strokes (they
    are filled shapes) — the epistemic probe is off-manifold by construction."""
    xd, _ = datasets.gen_digits(200, seed=4)
    xf, _ = datasets.gen_fashion(200, seed=4)
    area_d = (xd > 96).mean()
    area_f = (xf > 96).mean()
    assert area_f > 1.3 * area_d


def test_blood_shapes_and_classes():
    x, y = datasets.gen_blood(64, seed=5)
    assert x.shape == (64, 3, 28, 28) and x.dtype == np.uint8
    assert set(np.unique(y)).issubset(set(range(7)))
    xo, yo = datasets.gen_blood(16, seed=6, ood=True)
    assert np.all(yo == 7)


def test_blood_morphology_knobs():
    """Class morphology must be visible in simple statistics."""
    rng_n = 300
    x, y = datasets.gen_blood(rng_n, seed=7)

    def cellsize(c):
        imgs = x[y == c].astype(np.float32) / 255.0
        # darker-than-background area near center ~ cell footprint
        return (imgs.mean(axis=1) < 0.75).mean()

    # platelets (6) are tiny; monocytes (4) are the largest
    assert cellsize(6) < cellsize(4)
    # eosinophils (1) are redder than lymphocytes (3)
    red_eo = (x[y == 1, 0].astype(float) - x[y == 1, 2].astype(float)).mean()
    red_ly = (x[y == 3, 0].astype(float) - x[y == 3, 2].astype(float)).mean()
    assert red_eo > red_ly


def test_blood_ood_is_reddish_lymphocyte_like():
    """Erythroblast cytoplasm is red-shifted vs lymphocyte (the OOD cue)."""
    xi, yi = datasets.gen_blood(300, seed=8)
    xo, _ = datasets.gen_blood(150, seed=9, ood=True)
    ly = xi[yi == 3].astype(np.float32)
    eb = xo.astype(np.float32)
    assert (eb[:, 0] - eb[:, 2]).mean() > (ly[:, 0] - ly[:, 2]).mean()


def test_blood_deterministic():
    x1, _ = datasets.gen_blood(8, seed=10)
    x2, _ = datasets.gen_blood(8, seed=10)
    np.testing.assert_array_equal(x1, x2)
