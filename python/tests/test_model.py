"""L2 model tests: shapes, parameter packing, SVI step semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


CFGS = [(1, 10), (3, 7)]  # (in_channels, n_classes) for digits / blood


def _theta(ic, nc, seed=0):
    return jnp.asarray(model.init_params(seed, ic, nc))


def _batch(ic, b=2, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 1, (b, ic, 28, 28)).astype(np.float32))
    eps = jnp.asarray(rng.normal(0, 1, (b, model.PROB_CH, 7, 7, 9)).astype(np.float32))
    return x, eps


@pytest.mark.parametrize("ic,nc", CFGS)
def test_param_layout_contiguous(ic, nc):
    specs = model.param_layout(ic, nc)
    off = 0
    for s in specs:
        assert s.offset == off
        off += s.size
    assert off == model.num_params(ic, nc)


@pytest.mark.parametrize("ic,nc", CFGS)
def test_unpack_roundtrip(ic, nc):
    theta = _theta(ic, nc)
    p = model.unpack(theta, ic, nc)
    for s in model.param_layout(ic, nc):
        want = np.asarray(theta[s.offset : s.offset + s.size]).reshape(s.shape)
        np.testing.assert_array_equal(np.asarray(p[s.name]), want)


@pytest.mark.parametrize("ic,nc", CFGS)
def test_fwd_shapes(ic, nc):
    theta = _theta(ic, nc)
    x, eps = _batch(ic, b=3)
    x3q = model.fwd_pre(theta, x, ic, nc)
    assert x3q.shape == (3, model.PROB_CH, 7, 7)
    logits = model.fwd_post(theta, x3q, x3q, ic, nc)
    assert logits.shape == (3, nc)
    logits_full = model.fwd_full(theta, x, eps, ic, nc)
    assert logits_full.shape == (3, nc)
    assert np.all(np.isfinite(np.asarray(logits_full)))


def test_pre_output_is_quantized():
    """fwd_pre output must be on the 8-bit DAC grid."""
    theta = _theta(1, 10)
    x, _ = _batch(1)
    x3q = np.asarray(model.fwd_pre(theta, x, 1, 10))
    lv = np.round(x3q / model.SCALE_DAC * 127.0)
    np.testing.assert_allclose(lv * model.SCALE_DAC / 127.0, x3q, atol=1e-6)
    assert lv.min() >= -128 and lv.max() <= 127


def test_full_equals_pre_prob_post_composition():
    """fwd_full == fwd_post(fwd_pre, quant(prob_conv(fwd_pre))) — the split
    the Rust serving path uses must agree with the monolithic surrogate."""
    from compile.kernels.photonic_conv import fake_quant8, prob_depthwise_conv3x3

    ic, nc = 1, 10
    theta = _theta(ic, nc)
    x, eps = _batch(ic, b=2, seed=3)
    p = model.unpack(theta, ic, nc)
    x3q = model.fwd_pre(theta, x, ic, nc)
    sigma = model.ste_sigma_floor(jax.nn.softplus(p["prob_rho"]), p["prob_mu"])
    d3 = prob_depthwise_conv3x3(x3q, p["prob_mu"], sigma, eps)
    d3q = fake_quant8(d3, model.SCALE_ADC)
    want = model.fwd_post(theta, x3q, d3q, ic, nc)
    got = model.fwd_full(theta, x, eps, ic, nc)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_stochasticity_only_from_eps():
    """Same eps -> identical logits; different eps -> different logits."""
    theta = _theta(1, 10)
    x, eps = _batch(1, b=2, seed=1)
    l1 = model.fwd_full(theta, x, eps, 1, 10)
    l2 = model.fwd_full(theta, x, eps, 1, 10)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    _, eps2 = _batch(1, b=2, seed=99)
    l3 = model.fwd_full(theta, x, eps2, 1, 10)
    assert not np.allclose(np.asarray(l1), np.asarray(l3))


def test_kl_positive_and_zero_at_prior():
    mu = jnp.zeros((4, 9))
    sig = jnp.full((4, 9), model.PRIOR_SIGMA)
    assert abs(float(model._kl_gauss(mu, sig, model.PRIOR_SIGMA))) < 1e-5
    assert float(model._kl_gauss(mu + 1.0, sig, model.PRIOR_SIGMA)) > 0
    assert float(model._kl_gauss(mu, sig * 0.3, model.PRIOR_SIGMA)) > 0


def test_train_step_decreases_loss():
    ic, nc = 1, 10
    theta = _theta(ic, nc)
    m = jnp.zeros_like(theta)
    v = jnp.zeros_like(theta)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.uniform(0, 1, (64, ic, 28, 28)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, nc, 64).astype(np.int32))
    eps = jnp.asarray(rng.normal(0, 1, (64, model.PROB_CH, 7, 7, 9)).astype(np.float32))
    step_fn = jax.jit(lambda t, m, v, s: model.train_step(
        t, m, v, s, x, y, eps, 1e-5, 3e-3, ic, nc))
    losses = []
    s = jnp.float32(0)
    for i in range(30):
        theta, m, v, loss, nll, kl, acc = step_fn(theta, m, v, s)
        s = s + 1
        losses.append(float(loss))
    # memorizing a fixed batch must reduce the loss substantially
    assert losses[-1] < losses[0] * 0.8, losses[:3] + losses[-3:]


def test_train_step_shapes_and_finiteness():
    ic, nc = 3, 7
    theta = _theta(ic, nc)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.uniform(0, 1, (64, ic, 28, 28)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, nc, 64).astype(np.int32))
    eps = jnp.asarray(rng.normal(0, 1, (64, model.PROB_CH, 7, 7, 9)).astype(np.float32))
    out = model.train_step(theta, jnp.zeros_like(theta), jnp.zeros_like(theta),
                           jnp.float32(0), x, y, eps, 1e-4, 1e-3, ic, nc)
    t2, m2, v2, loss, nll, kl, acc = out
    assert t2.shape == theta.shape and m2.shape == theta.shape
    for s in (loss, nll, kl, acc):
        assert np.isfinite(float(s))
    assert float(kl) >= 0 and 0 <= float(acc) <= 1
