//! Uncertainty disentanglement — the Fig. 5 community benchmark.
//!
//! Train on clean digits only; probe at prediction time with (i) held-out
//! clean digits (in-domain), (ii) ambiguous digit morphs (aleatoric
//! uncertainty — the *input* is unclear), and (iii) garment silhouettes
//! (epistemic uncertainty — the *model* has never seen anything like it).
//! The engine's MI/SE pair separates the three regimes, so the system can
//! not only detect uncertainty but reason about *which kind* it faces.
//!
//! ```bash
//! pbm train --dataset digits    # once
//! cargo run --release --example uncertainty_reasoning
//! ```

use anyhow::Result;
use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::{Engine, EngineConfig, ExecMode};
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::experiments::uncertainty::{build_report, eval_split};
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};
use photonic_bayes::util::mathstat::{mean, median};

fn main() -> Result<()> {
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, "digits")?;
    let trained = root.join("digits/params_trained.bin");
    if !trained.exists() {
        eprintln!("params_trained.bin missing — run `pbm train --dataset digits` first");
    }
    let params = if trained.exists() {
        ParamStore::load_bin(&arts.meta, &trained)?
    } else {
        ParamStore::load_init(&arts.meta, &root.join("digits"))?
    };

    let mut engine = Engine::new(
        arts,
        params,
        EngineConfig {
            n_samples: 10,
            mode: ExecMode::photonic(),
            policy: UncertaintyPolicy::ood_only(0.00308), // paper's threshold
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: 1,
            seed: 11,
            ..Default::default()
        },
    )?;

    let data = root.join("data");
    let id = Dataset::load(&data, "digits_test", DatasetKind::InDomain)?;
    let amb = Dataset::load(&data, "ambiguous", DatasetKind::Aleatoric)?;
    let fash = Dataset::load(&data, "fashion", DatasetKind::Epistemic)?;

    let limit = 400;
    println!("evaluating {limit} inputs per split (photonic mode, N = 10)...");
    let id_s = eval_split(&mut engine, &id, limit)?;
    let amb_s = eval_split(&mut engine, &amb, limit)?;
    let fash_s = eval_split(&mut engine, &fash, limit)?;

    // --- the three clusters of Fig. 5(e) ----------------------------------
    println!("\n== Fig. 5(e) cluster statistics (MI = epistemic, SE = aleatoric) ==");
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10}",
        "split", "mean MI", "med MI", "mean SE", "med SE"
    );
    for s in [&id_s, &amb_s, &fash_s] {
        println!(
            "{:<22} {:>10.4} {:>10.4} {:>10.3} {:>10.3}",
            s.name,
            mean(&s.mi),
            median(&s.mi),
            mean(&s.se),
            median(&s.se)
        );
    }
    println!("\nexpected ordering: fashion has the highest MI (epistemic);");
    println!("ambiguous has the highest SE at moderate MI (aleatoric).");

    // --- the Fig. 5(f) numbers --------------------------------------------
    let rep = build_report(id_s, fash_s, Some(amb_s), 10);
    println!("\n== Fig. 5(f) ==");
    print!("{}", rep.summary());

    // a compact text rendition of the scatter (log-binned counts)
    println!("\nMI–SE scatter (counts per region; rows = SE tercile, cols = MI tercile):");
    let rows = rep.scatter_rows();
    let mi_cut = median(&rows.iter().map(|r| r.0).collect::<Vec<_>>());
    let se_cut = median(&rows.iter().map(|r| r.1).collect::<Vec<_>>());
    for cluster in 0..3u8 {
        let name = ["in-domain", "ambiguous", "fashion"][cluster as usize];
        let mut q = [0usize; 4];
        for r in rows.iter().filter(|r| r.2 == cluster) {
            let hi_mi = r.0 > mi_cut;
            let hi_se = r.1 > se_cut;
            q[(hi_se as usize) * 2 + hi_mi as usize] += 1;
        }
        println!(
            "  {name:<10} loMI/loSE {:>4}  hiMI/loSE {:>4}  loMI/hiSE {:>4}  hiMI/hiSE {:>4}",
            q[0], q[1], q[2], q[3]
        );
    }
    println!("\n{}", engine.report());
    Ok(())
}
