//! Quickstart: the whole stack in one page.
//!
//! ```bash
//! make artifacts && cargo build --release
//! cargo run --release --example quickstart [-- photonic|digital|mean]
//! ```
//!
//! Loads the AOT artifacts, builds an engine on the chosen sampling backend
//! (default: the photonic machine simulator + PJRT executables), classifies
//! a few test digits with N = 10 stochastic passes, and prints the
//! per-input uncertainty breakdown — plus a taste of the entropy source
//! that powers it.

use anyhow::Result;
use photonic_bayes::backend::BackendKind;
use photonic_bayes::bnn::{Decision, UncertaintyPolicy};
use photonic_bayes::coordinator::{Engine, EngineConfig, ExecMode};
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::entropy::{nist, ChaoticLightSource};
use photonic_bayes::photonics::{timing, MachineConfig};
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};

fn main() -> Result<()> {
    let root = artifacts_root();
    let backend = match std::env::args().nth(1) {
        Some(s) => BackendKind::parse(&s)?,
        None => BackendKind::Photonic,
    };

    // --- 1. the machine's headline numbers, derived from its constants ----
    let h = timing::headline();
    println!("photonic Bayesian machine:");
    println!("  {:.1} ps per probabilistic convolution", h.symbol_period_ps);
    println!("  {:.2} G convolutions/s, {:.2} Tbit/s digital interface\n",
        h.convolutions_per_sec / 1e9, h.interface_tbit_per_sec);

    // --- 2. the chaotic-light entropy source passes NIST SP800-22 --------
    let mut src = ChaoticLightSource::with_defaults(7);
    let bits = src.extract_bits(100.0, 20_000);
    let passed = nist::run_battery(&bits).iter().filter(|r| r.pass).count();
    println!(
        "entropy source: {passed}/{} NIST SP800-22 tests pass on 20 kbit\n",
        nist::run_battery(&bits).len()
    );

    // --- 3. load artifacts + (trained, if available) parameters ----------
    let arts = ModelArtifacts::load_dataset(&root, "digits")?;
    let trained = root.join("digits/params_trained.bin");
    let params = if trained.exists() {
        ParamStore::load_bin(&arts.meta, &trained)?
    } else {
        println!("note: params_trained.bin missing — run `pbm train --dataset digits`");
        ParamStore::load_init(&arts.meta, &root.join("digits"))?
    };

    // --- 4. build the engine: PJRT pre/post + photonic probabilistic block
    let mut engine = Engine::new(
        arts,
        params,
        EngineConfig {
            n_samples: 10,
            mode: ExecMode::Split(backend),
            policy: UncertaintyPolicy::full(0.02, 1.2),
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            // shard sampling across 4 workers; fix (seed, threads) to replay
            threads: 4,
            seed: 42,
            ..Default::default()
        },
    )?;

    // --- 5. classify some test digits -------------------------------------
    let ds = Dataset::load(&root.join("data"), "digits_test", DatasetKind::InDomain)?;
    let n = 8;
    let mut batch = Vec::new();
    for i in 0..n {
        batch.extend_from_slice(ds.image(i));
    }
    println!(
        "classifying {n} test digits with N = {} '{}' passes each:",
        engine.samples_per_request(),
        engine.backend_kind()
    );
    for (i, r) in engine.classify(&batch, n)?.iter().enumerate() {
        let verdict = match &r.decision {
            Decision::Accept { class, confidence } => {
                format!("accept class {class} (p = {confidence:.2})")
            }
            Decision::RejectOod { .. } => "REJECT (out-of-domain)".to_string(),
            Decision::FlagAmbiguous { class, .. } => format!("class {class} but AMBIGUOUS"),
        };
        println!(
            "  #{i}: true {} | {} | MI {:.4} SE {:.3} agreement {:.0}%",
            ds.labels[i],
            verdict,
            r.predictive.mutual_information,
            r.predictive.softmax_entropy,
            r.predictive.agreement * 100.0
        );
    }
    println!("\n{}", engine.report());
    Ok(())
}
