//! End-to-end serving driver: router + dynamic batcher + photonic engines
//! behind the TCP gateway, under Poisson client load.
//!
//! This is the E2E validation workload: it proves all layers compose —
//! AOT HLO artifacts (L2/L1) executed by PJRT, the photonic machine on the
//! request path (L3), dynamic batching, the wire protocol — and reports
//! serving latency/throughput percentiles.
//!
//! ```bash
//! cargo run --release --example serving_gateway [-- n_requests rate_hz]
//! ```

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;
use photonic_bayes::bnn::UncertaintyPolicy;
use photonic_bayes::coordinator::service::{EngineHandle, ServiceConfig};
use photonic_bayes::coordinator::{EngineConfig, ExecMode, Router};
use photonic_bayes::data::synth::poisson_arrivals_us;
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::entropy::Xoshiro256pp;
use photonic_bayes::exec::CancelToken;
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::server::{serve, Client, ServerOptions};
use photonic_bayes::util::mathstat::{mean, percentile};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(120);
    let rate_hz: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(150.0);

    let root = artifacts_root();
    let trained = root.join("digits/params_trained.bin");
    let params_path = if trained.exists() {
        trained
    } else {
        eprintln!("warning: serving with untrained init params");
        root.join("digits/params_init.bin")
    };

    // --- spin up the router with one photonic engine ----------------------
    let engine_cfg = EngineConfig {
        n_samples: 10,
        mode: ExecMode::photonic(),
        policy: UncertaintyPolicy::ood_only(0.00308),
        calibrate: false, // load-time speed; calibration is exercised elsewhere
        machine: MachineConfig::default(),
        noise_bw_ghz: 150.0,
        threads: 0, // one sampling worker per core: gateway throughput first
        // background entropy producers keep the sampling workers fed
        entropy_prefetch: photonic_bayes::coordinator::PrefetchMode::On,
        seed: 42,
        ..Default::default()
    };
    let svc_cfg = ServiceConfig {
        max_batch: 8,
        max_wait: Duration::from_millis(2),
        queue_depth: 512,
        ..Default::default()
    };
    let mut router = Router::new();
    router.register(EngineHandle::spawn(
        &root,
        "digits",
        Some(&params_path),
        engine_cfg,
        svc_cfg,
    )?);

    let cancel = CancelToken::new();
    let bound: Arc<Mutex<Option<std::net::SocketAddr>>> = Arc::new(Mutex::new(None));
    let bound2 = bound.clone();
    let cancel_srv = cancel.clone();
    let server = std::thread::spawn(move || {
        serve(
            router,
            ServerOptions {
                addr: "127.0.0.1:0".into(),
                workers: 8,
                ..Default::default()
            },
            cancel_srv,
            move |addr| {
                *bound2.lock().unwrap() = Some(addr);
            },
        )
    });
    let addr = loop {
        if let Some(a) = *bound.lock().unwrap() {
            break a;
        }
        std::thread::sleep(Duration::from_millis(10));
    };
    println!("gateway listening on {addr}");

    // --- Poisson client load ----------------------------------------------
    let ds = Dataset::load(&root.join("data"), "digits_test", DatasetKind::InDomain)?;
    let mut rng = Xoshiro256pp::new(99);
    let gaps = poisson_arrivals_us(&mut rng, rate_hz, n_requests);
    println!("firing {n_requests} requests at ~{rate_hz:.0} req/s (4 client connections)...");

    let t_start = Instant::now();
    let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
    let mut clients = Vec::new();
    let per_client = n_requests / 4;
    for c in 0..4 {
        let lat = latencies.clone();
        let addr = addr.to_string();
        let images: Vec<Vec<f32>> = (0..per_client)
            .map(|i| ds.image((c * per_client + i) % ds.n).to_vec())
            .collect();
        let gaps: Vec<f64> = gaps[c * per_client..(c + 1) * per_client].to_vec();
        clients.push(std::thread::spawn(move || -> Result<usize> {
            let mut client = Client::connect(&addr)?;
            let mut ok = 0usize;
            for (img, gap) in images.iter().zip(gaps) {
                std::thread::sleep(Duration::from_micros((gap * 4.0) as u64));
                let t0 = Instant::now();
                let resp = client.classify("digits", img)?;
                let us = t0.elapsed().as_micros() as f64;
                if resp.get("ok").and_then(|v| v.as_bool()) == Some(true) {
                    ok += 1;
                }
                lat.lock().unwrap().push(us);
            }
            Ok(ok)
        }));
    }
    let mut total_ok = 0;
    for c in clients {
        total_ok += c.join().unwrap()?;
    }
    let wall = t_start.elapsed().as_secs_f64();

    // --- report ------------------------------------------------------------
    let lat = latencies.lock().unwrap().clone();
    println!("\n== serving report ==");
    println!("  completed: {total_ok}/{} ok in {wall:.2}s ({:.1} req/s)",
        4 * per_client, total_ok as f64 / wall);
    println!(
        "  latency: mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms  p99 {:.1} ms",
        mean(&lat) / 1e3,
        percentile(&lat, 50.0) / 1e3,
        percentile(&lat, 95.0) / 1e3,
        percentile(&lat, 99.0) / 1e3
    );
    println!("  (each request = 10 stochastic photonic passes, dynamic batch <= 8)");

    cancel.cancel();
    server.join().unwrap()?;
    Ok(())
}
