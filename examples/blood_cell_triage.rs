//! Blood-cell triage — the paper's safety-critical scenario (Fig. 4).
//!
//! An AI-assisted hematology workstation: microscope images of blood cells
//! arrive, the hybrid BNN classifies the seven known cell types, and the
//! mutual-information triage policy escalates anything that looks like a
//! cell type the model was never trained on (erythroblasts — red-cell
//! precursors excluded from the training set) to a human practitioner.
//!
//! ```bash
//! pbm train --dataset blood     # once
//! cargo run --release --example blood_cell_triage
//! ```

use anyhow::Result;
use photonic_bayes::bnn::{Decision, UncertaintyPolicy};
use photonic_bayes::coordinator::{Engine, EngineConfig, ExecMode};
use photonic_bayes::data::{Dataset, DatasetKind};
use photonic_bayes::experiments::uncertainty::{build_report, eval_split};
use photonic_bayes::photonics::MachineConfig;
use photonic_bayes::runtime::artifact::artifacts_root;
use photonic_bayes::runtime::{ModelArtifacts, ParamStore};

const CELL_TYPES: [&str; 7] = [
    "basophil", "eosinophil", "imm.gran.", "lymphocyte",
    "monocyte", "neutrophil", "platelet",
];

fn main() -> Result<()> {
    let root = artifacts_root();
    let arts = ModelArtifacts::load_dataset(&root, "blood")?;
    let trained = root.join("blood/params_trained.bin");
    if !trained.exists() {
        eprintln!("params_trained.bin missing — run `pbm train --dataset blood` first");
    }
    let params = if trained.exists() {
        ParamStore::load_bin(&arts.meta, &trained)?
    } else {
        ParamStore::load_init(&arts.meta, &root.join("blood"))?
    };

    let mut engine = Engine::new(
        arts,
        params,
        EngineConfig {
            n_samples: 10,
            mode: ExecMode::photonic(),
            policy: UncertaintyPolicy::ood_only(0.0185), // paper's threshold
            calibrate: true,
            machine: MachineConfig::default(),
            noise_bw_ghz: 150.0,
            threads: 1,
            seed: 7,
            ..Default::default()
        },
    )?;

    let data = root.join("data");
    let id = Dataset::load(&data, "blood_test", DatasetKind::InDomain)?;
    let ood = Dataset::load(&data, "blood_ood", DatasetKind::Epistemic)?;

    // --- triage a mixed incoming stream (what the practitioner sees) ------
    println!("== incoming slide stream (mixed known cells + erythroblasts) ==");
    let mut stream: Vec<(usize, bool)> = (0..6).map(|i| (i, false)).collect();
    stream.extend((0..4).map(|i| (i, true)));
    for &(idx, is_ood) in &stream {
        let ds = if is_ood { &ood } else { &id };
        let results = engine.classify(ds.image(idx), 1)?;
        let r = &results[0];
        let truth = if is_ood {
            "erythroblast (UNKNOWN to model)".to_string()
        } else {
            CELL_TYPES[ds.labels[idx] as usize].to_string()
        };
        let action = match &r.decision {
            Decision::Accept { class, confidence } => {
                format!("report {} (p = {:.2})", CELL_TYPES[*class], confidence)
            }
            Decision::RejectOod { mutual_information } => format!(
                "ESCALATE to practitioner (MI = {mutual_information:.4} > 0.0185)"
            ),
            Decision::FlagAmbiguous { class, .. } => {
                format!("report {} with ambiguity flag", CELL_TYPES[*class])
            }
        };
        println!("  slide[{truth:<32}] -> {action}");
    }

    // --- the Fig. 4 panels over a larger evaluation ------------------------
    println!("\n== Fig. 4 evaluation (photonic mode, N = 10) ==");
    let id_scores = eval_split(&mut engine, &id, 400)?;
    let ood_scores = eval_split(&mut engine, &ood, 300)?;
    let rep = build_report(id_scores, ood_scores, None, 7);
    print!("{}", rep.summary());
    println!("\nFig. 4(d) confusion matrix (x = erythroblast OOD):");
    println!("{}", rep.confusion.render(&CELL_TYPES));
    println!("{}", engine.report());
    Ok(())
}
